"""Parameter schema — one declarative table per architecture.

The schema is the bridge between the model zoo and the DiOMP runtime: every
parameter declares its global shape and *logical* placement axes once, and
from that single declaration we derive

* materialized init (smoke tests / examples),
* ``ShapeDtypeStruct`` stand-ins (the dry-run never allocates),
* ``PartitionSpec`` in_specs for the manual shard_map step,
* PGAS registration rows (GlobalMemory arena planning).

Shardability rules are decided against the *production* TP width
(``MAX_TP = 16``): a dim is sharded over "model" only if it stays divisible
there (then it is automatically divisible on the smaller smoke meshes).
Q/KV heads that do not divide fall back to replicated weights + the
token-parallel attention path (DESIGN.md §5, e.g. paligemma's 8 heads).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

__all__ = [
    "MAX_TP", "ParamSpec", "build_schema", "init_params", "param_structs",
    "partition_specs", "head_parallel", "kv_sharded", "vocab_sharded",
]

MAX_TP = 16  # the production "model" axis width


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: str = "bfloat16"
    init: str = "normal"          # normal | zeros | ones
    scale: float = 0.02
    per_expert: bool = False      # for active-param accounting


# -- shardability predicates (shared with layers.py) -------------------------

def head_parallel(cfg: ModelConfig) -> bool:
    return cfg.num_heads > 0 and cfg.num_heads % MAX_TP == 0


def kv_sharded(cfg: ModelConfig) -> bool:
    return cfg.kv_heads > 0 and cfg.kv_heads % MAX_TP == 0


def vocab_sharded(cfg: ModelConfig) -> bool:
    return cfg.vocab_size % MAX_TP == 0


def _heads_ax(cfg) -> Optional[str]:
    return "heads" if head_parallel(cfg) else None


def _kv_ax(cfg) -> Optional[str]:
    return "kv_heads" if kv_sharded(cfg) else None


def _vocab_ax(cfg) -> Optional[str]:
    return "vocab" if vocab_sharded(cfg) else None


# -- per-family builders ------------------------------------------------------

def _dense_layer(cfg: ModelConfig, L: int, d_ff: int, prefix: str,
                 s: Dict[str, ParamSpec]) -> None:
    """One stacked block of standard GQA decoder/encoder layers."""
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.head_dim
    ha, ka = _heads_ax(cfg), _kv_ax(cfg)
    s[f"{prefix}/attn_norm"] = ParamSpec((L, d), (None, None), init="ones")
    s[f"{prefix}/wq"] = ParamSpec((L, d, H * hd), (None, "embed_fsdp", ha))
    s[f"{prefix}/wk"] = ParamSpec((L, d, KV * hd), (None, "embed_fsdp", ka))
    s[f"{prefix}/wv"] = ParamSpec((L, d, KV * hd), (None, "embed_fsdp", ka))
    if cfg.qkv_bias:
        s[f"{prefix}/bq"] = ParamSpec((L, H * hd), (None, ha), init="zeros")
        s[f"{prefix}/bk"] = ParamSpec((L, KV * hd), (None, ka), init="zeros")
        s[f"{prefix}/bv"] = ParamSpec((L, KV * hd), (None, ka), init="zeros")
    s[f"{prefix}/wo"] = ParamSpec((L, H * hd, d), (None, ha, "embed_fsdp"))
    s[f"{prefix}/mlp_norm"] = ParamSpec((L, d), (None, None), init="ones")
    s[f"{prefix}/w_gate"] = ParamSpec((L, d, d_ff), (None, "embed_fsdp", "mlp"))
    s[f"{prefix}/w_up"] = ParamSpec((L, d, d_ff), (None, "embed_fsdp", "mlp"))
    s[f"{prefix}/w_down"] = ParamSpec((L, d_ff, d), (None, "mlp", "embed_fsdp"))


def _mla_layer(cfg: ModelConfig, L: int, prefix: str,
               s: Dict[str, ParamSpec]) -> None:
    """DeepSeek multi-head latent attention block (+ its FFN slot is added
    separately as dense or MoE)."""
    d, H = cfg.d_model, cfg.num_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ha = _heads_ax(cfg)
    s[f"{prefix}/attn_norm"] = ParamSpec((L, d), (None, None), init="ones")
    s[f"{prefix}/wq_a"] = ParamSpec((L, d, qr), (None, "embed_fsdp", None))
    s[f"{prefix}/q_norm"] = ParamSpec((L, qr), (None, None), init="ones")
    s[f"{prefix}/wq_b"] = ParamSpec((L, qr, H * (dn + dr)), (None, "embed_fsdp", ha))
    s[f"{prefix}/wkv_a"] = ParamSpec((L, d, kr + dr), (None, "embed_fsdp", None))
    s[f"{prefix}/kv_norm"] = ParamSpec((L, kr), (None, None), init="ones")
    s[f"{prefix}/wkv_b"] = ParamSpec((L, kr, H * (dn + dv)), (None, "embed_fsdp", ha))
    s[f"{prefix}/wo"] = ParamSpec((L, H * dv, d), (None, ha, "embed_fsdp"))


def _moe_ffn(cfg: ModelConfig, L: int, prefix: str,
             s: Dict[str, ParamSpec]) -> None:
    d, E, ffm = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    s[f"{prefix}/mlp_norm"] = ParamSpec((L, d), (None, None), init="ones")
    s[f"{prefix}/router"] = ParamSpec((L, d, E), (None, None, None),
                                      dtype="float32", scale=0.006)
    s[f"{prefix}/w_gate_e"] = ParamSpec(
        (L, E, d, ffm), (None, "expert", "embed_fsdp", None), per_expert=True)
    s[f"{prefix}/w_up_e"] = ParamSpec(
        (L, E, d, ffm), (None, "expert", "embed_fsdp", None), per_expert=True)
    s[f"{prefix}/w_down_e"] = ParamSpec(
        (L, E, ffm, d), (None, "expert", None, "embed_fsdp"), per_expert=True)
    if cfg.shared_experts:
        ffs = ffm * cfg.shared_experts
        s[f"{prefix}/w_gate_s"] = ParamSpec((L, d, ffs), (None, "embed_fsdp", "mlp"))
        s[f"{prefix}/w_up_s"] = ParamSpec((L, d, ffs), (None, "embed_fsdp", "mlp"))
        s[f"{prefix}/w_down_s"] = ParamSpec((L, ffs, d), (None, "mlp", "embed_fsdp"))


def _rwkv_layer(cfg: ModelConfig, L: int, s: Dict[str, ParamSpec]) -> None:
    d, ff, lora = cfg.d_model, cfg.d_ff, 64
    s["layers/ln1"] = ParamSpec((L, 2, d), (None, None, None), init="ones")
    s["layers/ln2"] = ParamSpec((L, 2, d), (None, None, None), init="ones")
    # time-mix: token-shift mixing coefficients for (r, k, v, w, g)
    s["layers/tm_mu"] = ParamSpec((L, 5, d), (None, None, None), init="ones",
                                  scale=0.5)
    s["layers/tm_w0"] = ParamSpec((L, d), (None, "heads"), init="zeros")
    s["layers/tm_wA"] = ParamSpec((L, d, lora), (None, None, None), scale=0.01)
    s["layers/tm_wB"] = ParamSpec((L, lora, d), (None, None, "heads"), scale=0.01)
    s["layers/tm_u"] = ParamSpec((L, d), (None, "heads"), init="zeros")
    for nm in ("wr", "wk", "wv", "wg"):
        s[f"layers/tm_{nm}"] = ParamSpec((L, d, d), (None, "embed_fsdp", "heads"))
    s["layers/tm_lnx"] = ParamSpec((L, d), (None, "heads"), init="ones")
    s["layers/tm_wo"] = ParamSpec((L, d, d), (None, "heads", "embed_fsdp"))
    # channel-mix
    s["layers/cm_mu"] = ParamSpec((L, 2, d), (None, None, None), init="ones",
                                  scale=0.5)
    s["layers/cm_wk"] = ParamSpec((L, d, ff), (None, "embed_fsdp", "mlp"))
    s["layers/cm_wv"] = ParamSpec((L, ff, d), (None, "mlp", "embed_fsdp"))
    s["layers/cm_wr"] = ParamSpec((L, d, d), (None, "embed_fsdp", "heads"))


def _mamba_layer(cfg: ModelConfig, L: int, s: Dict[str, ParamSpec]) -> None:
    d = cfg.d_model
    din = 2 * d
    nh = din // 64
    st, cw = cfg.ssm_state, cfg.conv_width
    s["layers/norm"] = ParamSpec((L, d), (None, None), init="ones")
    s["layers/w_x"] = ParamSpec((L, d, din), (None, "embed_fsdp", "heads"))
    s["layers/w_z"] = ParamSpec((L, d, din), (None, "embed_fsdp", "heads"))
    s["layers/w_bc"] = ParamSpec((L, d, 2 * st), (None, "embed_fsdp", None))
    s["layers/w_dt"] = ParamSpec((L, d, nh), (None, "embed_fsdp", "heads"))
    s["layers/dt_bias"] = ParamSpec((L, nh), (None, "heads"), init="zeros")
    s["layers/conv_w"] = ParamSpec((L, cw, din), (None, None, "heads"), scale=0.1)
    s["layers/conv_b"] = ParamSpec((L, din), (None, "heads"), init="zeros")
    s["layers/A_log"] = ParamSpec((L, nh), (None, "heads"), init="zeros")
    s["layers/D"] = ParamSpec((L, nh), (None, "heads"), init="ones")
    s["layers/out_norm"] = ParamSpec((L, din), (None, "heads"), init="ones")
    s["layers/w_out"] = ParamSpec((L, din, d), (None, "heads", "embed_fsdp"))


# -- the public schema builder ------------------------------------------------

def build_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    s: Dict[str, ParamSpec] = {}
    d, V = cfg.d_model, cfg.vocab_size
    va = _vocab_ax(cfg)
    s["embed/table"] = ParamSpec((V, d), (va, None), scale=1.0)
    s["final_norm"] = ParamSpec((d,), (None,), init="ones")

    if cfg.family in ("dense", "vlm", "audio"):
        _dense_layer(cfg, cfg.num_layers, cfg.d_ff, "layers", s)
        if cfg.family == "audio":
            s["embed_norm"] = ParamSpec((2, d), (None, None), init="ones")
            s["head"] = ParamSpec((d, V), ("embed_fsdp", None))
        elif cfg.family == "vlm":
            pass  # tied embeddings: logits reuse embed/table
        else:
            s["lm_head"] = ParamSpec((d, V), (None, va))
    elif cfg.family == "moe":
        kd = cfg.first_k_dense
        Lm = cfg.num_layers - kd
        if cfg.attention == "mla":
            if kd:
                _mla_layer(cfg, kd, "dense_layers", s)
                s["dense_layers/mlp_norm"] = ParamSpec((kd, d), (None, None), init="ones")
                s["dense_layers/w_gate"] = ParamSpec((kd, d, cfg.d_ff), (None, "embed_fsdp", "mlp"))
                s["dense_layers/w_up"] = ParamSpec((kd, d, cfg.d_ff), (None, "embed_fsdp", "mlp"))
                s["dense_layers/w_down"] = ParamSpec((kd, cfg.d_ff, d), (None, "mlp", "embed_fsdp"))
            _mla_layer(cfg, Lm, "layers", s)
        else:
            # GQA MoE (qwen3): attention part of _dense_layer, FFN replaced
            H, KV, hd = cfg.num_heads, cfg.kv_heads, cfg.head_dim
            ha, ka = _heads_ax(cfg), _kv_ax(cfg)
            s["layers/attn_norm"] = ParamSpec((Lm, d), (None, None), init="ones")
            s["layers/wq"] = ParamSpec((Lm, d, H * hd), (None, "embed_fsdp", ha))
            s["layers/wk"] = ParamSpec((Lm, d, KV * hd), (None, "embed_fsdp", ka))
            s["layers/wv"] = ParamSpec((Lm, d, KV * hd), (None, "embed_fsdp", ka))
            s["layers/wo"] = ParamSpec((Lm, H * hd, d), (None, ha, "embed_fsdp"))
        _moe_ffn(cfg, Lm, "layers", s)
        s["lm_head"] = ParamSpec((d, V), (None, va))
        if cfg.mtp:
            s["mtp/proj"] = ParamSpec((2 * d, d), ("embed_fsdp", None))
            s["mtp/norm_h"] = ParamSpec((d,), (None,), init="ones")
            s["mtp/norm_e"] = ParamSpec((d,), (None,), init="ones")
            _mla_layer(cfg, 1, "mtp/layer", s)
            s["mtp/layer/mlp_norm"] = ParamSpec((1, d), (None, None), init="ones")
            ffs = cfg.moe_d_ff * max(cfg.shared_experts, 1)
            s["mtp/layer/w_gate"] = ParamSpec((1, d, ffs), (None, "embed_fsdp", "mlp"))
            s["mtp/layer/w_up"] = ParamSpec((1, d, ffs), (None, "embed_fsdp", "mlp"))
            s["mtp/layer/w_down"] = ParamSpec((1, ffs, d), (None, "mlp", "embed_fsdp"))
    elif cfg.family == "ssm":  # rwkv6
        s["embed_norm"] = ParamSpec((2, d), (None, None), init="ones")
        _rwkv_layer(cfg, cfg.num_layers, s)
        s["lm_head"] = ParamSpec((d, V), (None, va))
    elif cfg.family == "hybrid":  # zamba2
        _mamba_layer(cfg, cfg.num_layers, s)
        # the SHARED attention+MLP block (one param set, reused)
        H, KV, hd = cfg.num_heads, cfg.kv_heads, cfg.head_dim
        ha, ka = _heads_ax(cfg), _kv_ax(cfg)
        s["shared/attn_norm"] = ParamSpec((d,), (None,), init="ones")
        s["shared/wq"] = ParamSpec((d, H * hd), ("embed_fsdp", ha))
        s["shared/wk"] = ParamSpec((d, KV * hd), ("embed_fsdp", ka))
        s["shared/wv"] = ParamSpec((d, KV * hd), ("embed_fsdp", ka))
        s["shared/wo"] = ParamSpec((H * hd, d), (ha, "embed_fsdp"))
        s["shared/mlp_norm"] = ParamSpec((d,), (None,), init="ones")
        s["shared/w_gate"] = ParamSpec((d, cfg.d_ff), ("embed_fsdp", "mlp"))
        s["shared/w_up"] = ParamSpec((d, cfg.d_ff), ("embed_fsdp", "mlp"))
        s["shared/w_down"] = ParamSpec((cfg.d_ff, d), ("mlp", "embed_fsdp"))
        s["lm_head"] = ParamSpec((d, V), (None, va))
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return s


# -- derivations ---------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    """Materialize parameters (reduced configs / smoke tests only)."""
    schema = build_schema(cfg)
    out = {}
    keys = jax.random.split(key, len(schema))
    for k, (name, spec) in zip(keys, sorted(schema.items())):
        dt = jnp.dtype(spec.dtype)
        if spec.init == "zeros":
            out[name] = jnp.zeros(spec.shape, dt)
        elif spec.init == "ones":
            out[name] = jnp.ones(spec.shape, dt)
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = min(spec.scale, 1.0 / math.sqrt(max(fan_in, 1)))
            out[name] = (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dt)
    return out


def param_structs(cfg: ModelConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract stand-ins for the dry-run — zero allocation."""
    return {
        name: jax.ShapeDtypeStruct(spec.shape, jnp.dtype(spec.dtype))
        for name, spec in build_schema(cfg).items()
    }


def partition_specs(cfg: ModelConfig, mesh, rules=None) -> Dict[str, object]:
    """PartitionSpec per param (shard_map in_specs / NamedSharding)."""
    from repro.distributed.sharding import DEFAULT_RULES, logical_to_spec

    rules = rules or DEFAULT_RULES
    return {
        name: logical_to_spec(spec.axes, mesh, rules)
        for name, spec in build_schema(cfg).items()
    }

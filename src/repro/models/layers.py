"""Manual-SPMD layer library.

Every function here runs *inside* ``shard_map`` on device-local shards and
issues all cross-device traffic explicitly through OMPCCL / RMA verbs — the
DiOMP discipline: communication is owned by the runtime's verbs, never
implicit.  Layout conventions (DESIGN.md §4):

* activations: (B_loc, T, d) — batch sharded over (pod, data); d full;
  replicated over "model";
* weights: TP dim sharded over "model" (column/row Megatron style), the
  other big dim sharded over "data" (ZeRO-3 / FSDP) and all-gathered at use
  (optionally via the Cannon-style ring to overlap transfer with compute);
* attention: head-parallel when heads divide MAX_TP, token-parallel
  otherwise; decode caches are head-sharded, context(seq)-sharded, or
  replicated per the same divisibility rules.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ompccl
from repro.core.compat import axis_size
from repro.core.groups import DiompGroup
from repro.core.rma import ompx_put
from repro.kernels.flash_attention.ops import flash_attention
from .config import ModelConfig, ParallelCtx
from .schema import MAX_TP, head_parallel, kv_sharded, vocab_sharded

__all__ = [
    "rmsnorm", "layernorm", "rope", "gather_fsdp", "tp_allreduce",
    "col_matmul", "row_matmul", "embed_lookup", "ce_loss",
    "attention_block", "mla_block", "mlp_block", "moe_block",
    "moe_capacity", "cp_decode_attention",
]

F32 = jnp.float32


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-5, plus_one: bool = False):
    xf = x.astype(F32)
    inv = lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    s = scale.astype(F32)
    if plus_one:
        s = 1.0 + s
    return (xf * inv * s).astype(x.dtype)


def layernorm(x, scale_bias, eps: float = 1e-5):
    """scale_bias: (2, d) — row 0 scale, row 1 bias."""
    xf = x.astype(F32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * scale_bias[0].astype(F32) + scale_bias[1].astype(F32)).astype(x.dtype)


def rope(x, positions, *, theta: float = 10_000.0, fraction: float = 1.0):
    """x: (B, T, H, D); positions: (T,) or (B, T) (per-slot decode offsets)."""
    D = x.shape[-1]
    rot = int(D * fraction) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    pos = positions.astype(F32)
    if pos.ndim == 1:
        pos = pos[None, :]                                      # (1, T)
    ang = pos[..., None] * freqs[None, None, :]                 # (B|1, T, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = xr[..., :half].astype(F32), xr[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# communication helpers (all traffic through OMPCCL / RMA)
# ---------------------------------------------------------------------------

def gather_fsdp(w, ctx: ParallelCtx, dim: int = 0):
    """ZeRO-3 weight all-gather over the data axis (no-op if fsdp == 1).

    AD transposes this to a reduce-scatter of the weight gradient over the
    same axis — the intra-pod half of the hierarchical gradient reduction.

    ``ctx.gather_codec == "int8"``: the wire moves int8 + one f32 scale per
    shard (2x fewer bytes than bf16).  Remote shards are dequantized; my own
    shard is spliced back at full precision through a straight-through
    estimator, so gradients flow to the unquantized weights and the grad
    reduce-scatter stays exact.
    """
    if ctx.fsdp <= 1 or not ctx.fsdp_params:
        return w                      # inference WS: weights arrive whole
    if ctx.gather_codec == "int8":
        return _q8_gather(w, ctx, dim)
    return ompccl.allgather(w, ctx.fsdp_group, axis=dim,
                            invariant=ctx.inference)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _q8_gather(w, ctx, dim):
    """int8-wire ZeRO-3 gather (ZeRO++ qwZ-style).

    Forward: quantize the local shard, all-gather int8 + per-shard scales,
    dequantize, splice my own shard back at full precision.  Backward: the
    exact reduce-scatter of the cotangent (identical to plain all_gather's
    transpose) — the grad wire stays uncompressed and exact.
    """
    from repro.distributed.compression import quantize_int8

    q, s = quantize_int8(w)
    qq = ompccl.allgather(q, ctx.fsdp_group, axis=dim,
                          invariant=ctx.inference)
    ss = ompccl.allgather(s.reshape(1), ctx.fsdp_group, axis=0,
                          invariant=ctx.inference)         # (fsdp,)
    n = ss.shape[0]
    shard = qq.shape[dim] // n
    scale_shape = [1] * qq.ndim
    scale_shape[dim] = n
    scales = jnp.repeat(ss.reshape(scale_shape), shard, axis=dim)
    full = (qq.astype(F32) * scales).astype(w.dtype)
    idx = lax.axis_index(ctx.fsdp_group.axes[0])
    return lax.dynamic_update_slice_in_dim(full, w, idx * shard, axis=dim)


def _q8_gather_fwd(w, ctx, dim):
    return _q8_gather(w, ctx, dim), None


def _q8_gather_bwd(ctx, dim, _res, g):
    return (ompccl.reducescatter(g, ctx.fsdp_group, axis=dim)
            .astype(g.dtype),)


_q8_gather.defvjp(_q8_gather_fwd, _q8_gather_bwd)


def ring_fsdp_matmul(x, w_local, ctx: ParallelCtx):
    """Cannon-style overlap of the ZeRO-3 gather: y = x @ W, W row-sharded.

    Instead of all-gathering W then one GEMM, rotate W shards around the
    data-axis ring; each step's ompx_put overlaps the concurrent partial
    GEMM (paper §4.4 generalized to the weight gather).

    The step schedule comes from the shared
    :class:`~repro.kernels.plan.OverlapPlanner`: ``ctx.ring_impl="fused"``
    (the default resolution of ``"auto"``) runs the bidirectional ring —
    W stripes circulate both ways, ``ceil((n-1)/2)`` exchange steps, both
    link directions busy; ``"host"`` keeps the unidirectional ``n-1``-step
    loop.  Both are differentiable (the puts are ppermutes), so this is
    the path the TP layers train through.
    """
    if ctx.fsdp <= 1 or not ctx.fsdp_params:
        return jnp.dot(x, w_local, preferred_element_type=F32).astype(x.dtype)
    from repro.core.vma import zeros_varying
    from repro.kernels.plan import RingPlan, resolve_ring_impl

    group = ctx.fsdp_group
    n = axis_size(group.axes[0])
    idx = lax.axis_index(group.axes[0])
    dshard = w_local.shape[0]
    direction = ("bidi" if resolve_ring_impl(ctx.ring_impl) == "fused"
                 else "cw")
    # only the step schedule matters here: the stripes live as XLA values,
    # not planned VMEM slots (this is the host-level, differentiable form)
    plan = RingPlan(n=n, direction=direction)
    acc = zeros_varying(x.shape[:-1] + (w_local.shape[1],), F32, x)

    def partial_gemm(acc, w_stripe, src):
        xs = lax.dynamic_slice_in_dim(x, src * dshard, dshard, axis=-1)
        return acc + jnp.dot(xs, w_stripe, preferred_element_type=F32)

    cw = ccw = w_local
    for st in plan.schedule():
        # forwards first: the next stripes fly while this step's GEMMs run
        cw_next = ompx_put(cw, group, shift=1) if st.send_cw else cw
        ccw_next = ompx_put(ccw, group, shift=-1) if st.send_ccw else ccw
        if st.compute_cw:
            acc = partial_gemm(acc, cw, (idx - st.index) % n)
        if st.compute_ccw:
            acc = partial_gemm(acc, ccw, (idx + st.index) % n)
        cw, ccw = cw_next, ccw_next
    return acc.astype(x.dtype)


def tp_allreduce(x, ctx: ParallelCtx):
    if ctx.tp <= 1:
        return x
    return ompccl.allreduce(x, ctx.tp_group)


def col_matmul(x, w_local, ctx: ParallelCtx, bias_local=None):
    """Megatron column-parallel: x (…, d) × W (d/fsdp, out/tp) -> (…, out/tp)."""
    if ctx.use_ring_matmul:
        y = ring_fsdp_matmul(x, w_local, ctx)
    else:
        w = gather_fsdp(w_local, ctx, dim=0)
        y = jnp.dot(x, w, preferred_element_type=F32).astype(x.dtype)
    if bias_local is not None:
        y = y + bias_local.astype(y.dtype)
    return y


def row_matmul(x, w_local, ctx: ParallelCtx):
    """Megatron row-parallel: x (…, in/tp) × W (in/tp, d/fsdp) -> allreduced (…, d)."""
    w = gather_fsdp(w_local, ctx, dim=1)
    y = jnp.dot(x, w, preferred_element_type=F32).astype(x.dtype)
    return tp_allreduce(y, ctx)


# ---------------------------------------------------------------------------
# embedding / loss (vocab-sharded over the TP group)
# ---------------------------------------------------------------------------

def embed_lookup(tokens, table_local, cfg: ModelConfig, ctx: ParallelCtx):
    """tokens: (B, T) int32; table_local: (V/tp, d) or (V, d)."""
    if not vocab_sharded(cfg) or ctx.tp <= 1:
        return table_local[tokens]
    vloc = table_local.shape[0]
    off = lax.axis_index(ctx.tp_group.axes[0]) * vloc
    local = tokens - off
    hit = (local >= 0) & (local < vloc)
    e = table_local[jnp.clip(local, 0, vloc - 1)]
    e = jnp.where(hit[..., None], e, jnp.zeros_like(e))
    return tp_allreduce(e, ctx)


def ce_loss(h, head_local, targets, cfg: ModelConfig, ctx: ParallelCtx,
            weights=None):
    """Cross-entropy with vocab-sharded logits.

    h: (B, T, d); head_local: (d, V/tp) (or (d, V) unsharded); targets (B, T).
    The softmax statistics are reduced across the TP group with explicit
    OMPCCL max/sum collectives (the paper's device-side collectives in the
    loss path).  Returns mean loss (f32).
    """
    logits = jnp.dot(h.astype(F32), head_local.astype(F32))   # (B, T, V/tp)
    sharded = vocab_sharded(cfg) and ctx.tp > 1
    m = lax.stop_gradient(logits).max(axis=-1)
    if sharded:
        m = ompccl.allreduce(m, ctx.tp_group, op="max")
    m = lax.stop_gradient(m)  # the max shift carries no gradient (and pmax
    # has no AD rule); the CE gradient is exact regardless of the shift
    z = jnp.exp(logits - m[..., None]).sum(axis=-1)
    if sharded:
        z = ompccl.allreduce(z, ctx.tp_group)
    if sharded:
        vloc = head_local.shape[1]
        off = lax.axis_index(ctx.tp_group.axes[0]) * vloc
        local = targets - off
        hit = (local >= 0) & (local < vloc)
        tgt = jnp.take_along_axis(
            logits, jnp.clip(local, 0, vloc - 1)[..., None], axis=-1
        )[..., 0]
        tgt = jnp.where(hit, tgt, 0.0)
        tgt = ompccl.allreduce(tgt, ctx.tp_group)
    else:
        tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = jnp.log(z) + m - tgt
    if weights is not None:
        return (nll * weights).sum() / jnp.maximum(weights.sum(), 1.0)
    return nll.mean()


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KVCache:
    """Decode-time cache; a pytree (flax-free).  ``pos`` is a traced scalar."""

    k: jax.Array            # (B, S_cache_local, KH_local, D)
    v: jax.Array
    pos: jax.Array          # ()
    seq_sharded: bool = False   # context-parallel cache (S split over a group)

    def tree_flatten(self):
        return (self.k, self.v, self.pos), (self.seq_sharded,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, seq_sharded=aux[0])


jax.tree_util.register_pytree_node(
    KVCache, KVCache.tree_flatten, KVCache.tree_unflatten
)


def cp_decode_attention(q, cache: KVCache, group: DiompGroup, *, scale):
    """Decode attention over a context(S)-sharded KV cache.

    q: (B, 1, H, D); cache.k/v: (B, S/g, KH, D) — each group member holds an
    S-chunk.  Partial (max, sum, acc) per chunk are combined with OMPCCL
    max/sum collectives — distributed flash-decode.
    """
    B, _, H, D = q.shape
    s_loc = cache.k.shape[1]
    KH = cache.k.shape[2]
    Dv = cache.v.shape[-1]
    G = H // KH
    ax = group.axes[0]
    chunk_off = lax.axis_index(ax) * s_loc

    qf = q.astype(F32).reshape(B, KH, G, D) * scale
    kf = cache.k.astype(F32)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, kf)                  # (B, KH, G, S/g)
    k_pos = chunk_off + jnp.arange(s_loc)
    # cache.pos has already been advanced past the newly written entry, so
    # exactly the first ``pos`` slots are valid
    vis = k_pos[None, None, None, :] < cache.pos
    s = jnp.where(vis, s, -jnp.inf)

    m_loc = s.max(axis=-1)
    m = ompccl.allreduce(m_loc, group, op="max")
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.where(vis, jnp.exp(s - m_safe[..., None]), 0.0)
    l = ompccl.allreduce(p.sum(axis=-1), group)
    acc = jnp.einsum("bhgs,bshd->bhgd", p, cache.v.astype(F32))
    acc = ompccl.allreduce(acc, group)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


def _update_cache(cache: KVCache, k_new, v_new, group: Optional[DiompGroup]):
    """Write one decode step's K/V at cache.pos (context-sharded aware).

    ``cache.pos`` may be a scalar (uniform batch) or a (B,) vector
    (continuous batching: per-slot positions).
    """
    if jnp.ndim(cache.pos) == 1:  # per-slot positions
        def write(c, new, p):
            return lax.dynamic_update_slice(c, new.astype(c.dtype), (p, 0, 0))

        k = jax.vmap(write)(cache.k, k_new, cache.pos)
        v = jax.vmap(write)(cache.v, v_new, cache.pos)
        return KVCache(k, v, cache.pos + 1, seq_sharded=cache.seq_sharded)
    if cache.seq_sharded:
        assert group is not None
        s_loc = cache.k.shape[1]
        lo = lax.axis_index(group.axes[0]) * s_loc
        local = jnp.clip(cache.pos - lo, 0, s_loc - 1)
        in_range = (cache.pos >= lo) & (cache.pos < lo + s_loc)
        k_w = lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                       (0, local, 0, 0))
        v_w = lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                       (0, local, 0, 0))
        k = jnp.where(in_range, k_w, cache.k)
        v = jnp.where(in_range, v_w, cache.v)
    else:
        k = lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, cache.pos, 0, 0))
        v = lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, cache.pos, 0, 0))
    return KVCache(k, v, cache.pos + 1, seq_sharded=cache.seq_sharded)


def local_kv_heads(cfg: ModelConfig, ctx: ParallelCtx) -> int:
    """KV heads each device keeps (cache + attention operand width)."""
    if kv_sharded(cfg):
        return cfg.kv_heads // ctx.tp
    if head_parallel(cfg) and ctx.tp > 1:
        H_loc = cfg.num_heads // ctx.tp
        group = cfg.num_heads // cfg.kv_heads
        assert H_loc % group == 0 or group % H_loc == 0, (H_loc, group)
        return max(1, H_loc // group)
    return cfg.kv_heads


def _slice_kv(kv, cfg: ModelConfig, ctx: ParallelCtx):
    """With heads sharded but KV replicated, keep only the KV heads my local
    q-head block maps to (q head h -> kv head h // (H/KV))."""
    KV_keep = local_kv_heads(cfg, ctx)
    if KV_keep == kv.shape[2]:
        return kv
    H_loc = cfg.num_heads // ctx.tp
    group = cfg.num_heads // cfg.kv_heads
    first_q = lax.axis_index(ctx.tp_group.axes[0]) * H_loc
    return lax.dynamic_slice_in_dim(kv, first_q // group, KV_keep, axis=2)


def attention_block(
    x, lp: Dict[str, jax.Array], cfg: ModelConfig, ctx: ParallelCtx,
    *,
    positions=None,
    prefix_len: int = 0,
    cache: Optional[KVCache] = None,
    causal: Optional[bool] = None,
    chunked: bool = False,
):
    """GQA attention with residual-input x (B, T, d); returns (out, cache').

    Four execution strategies (DESIGN.md §5 + chunked serving prefill,
    docs/SERVING.md):
    * head-parallel  — q heads divide MAX_TP: heads sharded over "model";
    * token-parallel — otherwise (e.g. paligemma H=8): weights replicated
      over "model", the T axis is sliced instead;
    * decode         — T == 1 with a cache: head-sharded, replicated, or
      context(S)-sharded cache (cp_decode_attention);
    * chunked prefill — ``chunked=True`` with a cache and T > 1: the chunk's
      K/V are appended at the running ``cache.pos`` and the queries attend
      over the whole valid prefix (cached + chunk), so a prompt streams
      through the cache in ``ceil(len/chunk)`` device calls.
    """
    B, T, d = x.shape
    hp = head_parallel(cfg)
    kvs = kv_sharded(cfg)
    hd = cfg.head_dim
    H_loc = cfg.num_heads // ctx.tp if hp else cfg.num_heads
    KV_loc = cfg.kv_heads // ctx.tp if kvs else cfg.kv_heads
    causal = cfg.causal if causal is None else causal
    if positions is None:
        positions = jnp.arange(T)

    bq = lp.get("bq")
    bk = lp.get("bk")
    bv = lp.get("bv")

    decode = cache is not None and T == 1
    chunkfill = chunked and cache is not None and not decode
    token_parallel = ((not hp) and (not decode) and (not chunkfill)
                      and T % ctx.tp == 0 and ctx.tp > 1)

    # sequence-parallel context strategy (ctx.seq_parallel, resolved by the
    # step builders; "ring" rotates K/V stripes as one-sided puts folded
    # with the online-softmax merge instead of materializing full K/V)
    ring_attn = False
    if ctx.tp > 1 and not hp and not kvs:
        from repro.kernels.plan import resolve_seq_parallel

        ring_attn = resolve_seq_parallel(ctx.seq_parallel) == "ring"

    if token_parallel:
        t_loc = T // ctx.tp
        t0 = lax.axis_index(ctx.tp_group.axes[0]) * t_loc
        x_me = lax.dynamic_slice_in_dim(x, t0, t_loc, axis=1)
        pos_me = lax.dynamic_slice_in_dim(positions, t0, t_loc, axis=0)
    else:
        x_me, pos_me = x, positions

    q = col_matmul(x_me, lp["wq"], ctx, bq).reshape(*x_me.shape[:2], H_loc, hd)
    k = col_matmul(x_me, lp["wk"], ctx, bk).reshape(*x_me.shape[:2], KV_loc, hd)
    v = col_matmul(x_me, lp["wv"], ctx, bv).reshape(*x_me.shape[:2], KV_loc, hd)
    if hp and not kvs and ctx.tp > 1:
        # heads sharded, KV weights replicated: keep only my groups' KV heads
        k = _slice_kv(k, cfg, ctx)
        v = _slice_kv(v, cfg, ctx)
    if cfg.rope_fraction > 0:
        q = rope(q, pos_me, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
        k = rope(k, pos_me, theta=cfg.rope_theta, fraction=cfg.rope_fraction)

    new_cache = cache
    if decode:
        new_cache = _update_cache(
            cache, k, v,
            ctx.fsdp_group if cache.seq_sharded else None,
        )
        if cache.seq_sharded:
            attn = cp_decode_attention(q, new_cache, ctx.fsdp_group,
                                       scale=hd ** -0.5)
        else:
            attn = flash_attention(
                q, new_cache.k, new_cache.v, causal=True,
                q_offset=new_cache.pos - 1, valid_len=new_cache.pos,
            )  # pos may be scalar or (B,) — the ref kernel broadcasts
    elif chunkfill:
        # chunked prefill: append this chunk's K/V at the running cache
        # position and attend over the whole valid prefix.  Causal masking
        # with q_offset = pos keeps any padded tail of the chunk invisible
        # (padded keys sit strictly after every real query position), and
        # padded cache rows are overwritten by the next chunk/decode write
        # before any query can reach them.
        assert not cache.seq_sharded, \
            "chunked prefill does not support a context-sharded cache"
        p0 = cache.pos
        new_cache = KVCache(
            lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                     (0, p0, 0, 0)),
            lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                     (0, p0, 0, 0)),
            p0 + T, seq_sharded=False,
        )
        s_all = new_cache.k.shape[1]
        if ring_attn and s_all % ctx.tp == 0:
            # sequence-parallel chunked prefill: the cache is replicated
            # over "model", so each rank takes its S-stripe and the chunk's
            # (shared) queries ride the ring — every rank folds n stripes
            # of S/n keys instead of scanning the whole prefix.  q_offset /
            # valid_len are traced; the ring emulation masks dynamically.
            s_loc = s_all // ctx.tp
            me = lax.axis_index(ctx.tp_group.axes[0])
            k_str = lax.dynamic_slice_in_dim(new_cache.k, me * s_loc,
                                             s_loc, axis=1)
            v_str = lax.dynamic_slice_in_dim(new_cache.v, me * s_loc,
                                             s_loc, axis=1)
            attn = flash_attention(
                q, k_str, v_str, causal=True, impl="ring",
                group=ctx.tp_group, q_offset=p0, valid_len=p0 + T,
                q_sharded=False)
        else:
            attn = flash_attention(q, new_cache.k, new_cache.v, causal=True,
                                   q_offset=p0, valid_len=p0 + T)
    elif token_parallel and ring_attn and cache is None and prefix_len == 0:
        # fused ring attention (token-parallel training): the K/V shards
        # never materialize per-rank — stripes rotate through the
        # bidirectional one-sided ring while the online-softmax state
        # accumulates (O(T/n) context memory instead of O(T))
        attn = flash_attention(q, k, v, causal=causal, impl="ring",
                               group=ctx.tp_group, q_sharded=True)
    elif token_parallel:
        # KV must cover the full sequence: gather over the TP group
        k_full = ompccl.allgather(k, ctx.tp_group, axis=1,
                                  invariant=ctx.inference)
        v_full = ompccl.allgather(v, ctx.tp_group, axis=1,
                                  invariant=ctx.inference)
        attn = flash_attention(
            q, k_full, v_full, causal=causal, q_offset=t0,
            prefix_len=prefix_len,
        )
        if cache is not None:  # prefill: persist the gathered KV
            new_cache = KVCache(
                lax.dynamic_update_slice(
                    cache.k, k_full.astype(cache.k.dtype), (0, 0, 0, 0)),
                lax.dynamic_update_slice(
                    cache.v, v_full.astype(cache.v.dtype), (0, 0, 0, 0)),
                jnp.asarray(T, jnp.int32), seq_sharded=False,
            )
    else:
        attn = flash_attention(q, k, v, causal=causal, prefix_len=prefix_len)
        if cache is not None:  # prefill into a decode cache
            new_cache = KVCache(
                lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                         (0, 0, 0, 0)),
                lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                         (0, 0, 0, 0)),
                jnp.asarray(T, jnp.int32), seq_sharded=False,
            )

    attn2 = attn.reshape(*attn.shape[:2], H_loc * hd)
    if token_parallel:
        out_me = jnp.dot(attn2, gather_fsdp(lp["wo"], ctx, dim=1),
                         preferred_element_type=F32).astype(x.dtype)
        out = ompccl.allgather(out_me, ctx.tp_group, axis=1,
                               invariant=ctx.inference)   # tokens back
    elif hp:
        out = row_matmul(attn2, lp["wo"], ctx)
    else:  # decode on replicated heads: wo replicated over model
        out = jnp.dot(attn2, gather_fsdp(lp["wo"], ctx, dim=1),
                      preferred_element_type=F32).astype(x.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MLACache:
    """Latent cache: c_kv (B, S, kr) + rope'd shared key (B, S, dr)."""

    c: jax.Array
    kr: jax.Array
    pos: jax.Array

    def tree_flatten(self):
        return (self.c, self.kr, self.pos), ()

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


jax.tree_util.register_pytree_node(
    MLACache, MLACache.tree_flatten, MLACache.tree_unflatten
)


def mla_block(
    x, lp, cfg: ModelConfig, ctx: ParallelCtx,
    *, positions=None, cache: Optional[MLACache] = None,
    chunked: bool = False,
):
    """DeepSeek-V3 multi-head latent attention.  Returns (out, cache').

    Train/prefill: decompress per-head K/V from the latent and run flash
    attention.  Decode: *absorbed* form — attention runs in the latent space
    against the (replicated, tiny) latent cache; only the final per-head
    up-projection touches head dims.  TP: heads sharded (128 % 16 == 0);
    the latent path is replicated (that is MLA's point: the cache is small).
    ``chunked=True`` (serving prefill, docs/SERVING.md): the chunk's latents
    are appended at the running ``cache.pos`` and the chunk's queries attend
    over K/V decompressed from the whole valid latent prefix.
    """
    B, T, d = x.shape
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kr_rank = cfg.kv_lora_rank
    H_loc = cfg.num_heads // ctx.tp if head_parallel(cfg) else cfg.num_heads
    if positions is None:
        positions = jnp.arange(T)
    scale = (dn + dr) ** -0.5

    cq = rmsnorm(col_matmul(x, lp["wq_a"], ctx), lp["q_norm"], cfg.norm_eps)
    q = col_matmul(cq, lp["wq_b"], ctx).reshape(B, T, H_loc, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, theta=cfg.rope_theta)

    ckv = col_matmul(x, lp["wkv_a"], ctx)                     # (B, T, kr+dr)
    c = rmsnorm(ckv[..., :kr_rank], lp["kv_norm"], cfg.norm_eps)
    k_rope = rope(ckv[..., None, kr_rank:], positions, theta=cfg.rope_theta)

    wkv_b = gather_fsdp(lp["wkv_b"], ctx, dim=0)              # (kr, H_loc*(dn+dv))
    wkv_b = wkv_b.reshape(kr_rank, H_loc, dn + dv)

    new_cache = cache
    if cache is not None and T == 1:
        # absorbed decode
        if jnp.ndim(cache.pos) == 1:  # per-slot positions
            wr = lambda cc, new, p: lax.dynamic_update_slice(
                cc, new.astype(cc.dtype), (p, 0))
            new_cache = MLACache(
                jax.vmap(wr)(cache.c, c, cache.pos),
                jax.vmap(wr)(cache.kr, k_rope[:, :, 0], cache.pos),
                cache.pos + 1,
            )
        else:
            new_cache = MLACache(
                lax.dynamic_update_slice(cache.c, c.astype(cache.c.dtype),
                                         (0, cache.pos, 0)),
                lax.dynamic_update_slice(cache.kr, k_rope[:, :, 0].astype(
                    cache.kr.dtype), (0, cache.pos, 0)),
                cache.pos + 1,
            )
        q_lat = jnp.einsum("bthn,khn->bthk", q_nope.astype(F32),
                           wkv_b[..., :dn].astype(F32))        # (B,1,H,kr)
        s = jnp.einsum("bthk,bsk->bhs", q_lat,
                       new_cache.c.astype(F32)) + jnp.einsum(
            "bthr,bsr->bhs", q_rope.astype(F32), new_cache.kr.astype(F32))
        s = s * scale
        k_pos = jnp.arange(new_cache.c.shape[1])
        vis = k_pos[None, None, :] < jnp.reshape(new_cache.pos, (-1, 1, 1))
        s = jnp.where(vis, s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1, where=vis)
        ctx_lat = jnp.einsum("bhs,bsk->bhk", p, new_cache.c.astype(F32))
        attn = jnp.einsum("bhk,khn->bhn", ctx_lat,
                          wkv_b[..., dn:].astype(F32))         # (B,H,dv)
        attn = attn[:, None].astype(x.dtype)                   # (B,1,H,dv)
    elif chunked and cache is not None:
        # chunked prefill: append latents at cache.pos, attend over the
        # decompressed full prefix (causal + q_offset mask the padded tail
        # and the unwritten suffix, exactly as in attention_block)
        p0 = cache.pos
        new_cache = MLACache(
            lax.dynamic_update_slice(cache.c, c.astype(cache.c.dtype),
                                     (0, p0, 0)),
            lax.dynamic_update_slice(
                cache.kr, k_rope[:, :, 0].astype(cache.kr.dtype), (0, p0, 0)),
            p0 + T,
        )
        S_all = new_cache.c.shape[1]
        kv_all = jnp.einsum("bsk,khn->bshn", new_cache.c.astype(F32),
                            wkv_b.astype(F32)).astype(x.dtype)
        k_nope_all, v_all = kv_all[..., :dn], kv_all[..., dn:]
        k_all = jnp.concatenate(
            [k_nope_all,
             jnp.broadcast_to(new_cache.kr[:, :, None].astype(x.dtype),
                              (B, S_all, H_loc, dr))], axis=-1)
        qkr = jnp.concatenate([q_nope, q_rope], axis=-1)
        attn = flash_attention(qkr, k_all, v_all, causal=True, scale=scale,
                               q_offset=p0, valid_len=p0 + T)
    else:
        kv = jnp.einsum("btk,khn->bthn", c.astype(F32),
                        wkv_b.astype(F32)).astype(x.dtype)     # decompress
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, T, H_loc, dr))], axis=-1)
        qkr = jnp.concatenate([q_nope, q_rope], axis=-1)
        attn = flash_attention(qkr, k, v, causal=True, scale=scale)
        if cache is not None:  # prefill the latent cache
            new_cache = MLACache(
                lax.dynamic_update_slice(cache.c, c.astype(cache.c.dtype),
                                         (0, 0, 0)),
                lax.dynamic_update_slice(
                    cache.kr, k_rope[:, :, 0].astype(cache.kr.dtype), (0, 0, 0)),
                jnp.asarray(T, jnp.int32),
            )

    out = row_matmul(attn.reshape(B, -1, H_loc * dv), lp["wo"], ctx)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_block(x, lp, ctx: ParallelCtx, *, act: str = "silu",
              names=("w_gate", "w_up", "w_down")):
    """SwiGLU/GeGLU column->row parallel MLP."""
    g, u, dwn = names
    h = col_matmul(x, lp[g], ctx)
    h = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    h = h * col_matmul(x, lp[u], ctx)
    return row_matmul(h, lp[dwn], ctx)


def gelu_mlp_block(x, lp, ctx: ParallelCtx):
    """Plain 2-matmul GELU MLP (hubert encoder): reuses w_up/w_down."""
    h = jax.nn.gelu(col_matmul(x, lp["w_up"], ctx))
    return row_matmul(h, lp["w_down"], ctx)


# ---------------------------------------------------------------------------
# MoE (expert-parallel over the "model" axis, all_to_all dispatch)
# ---------------------------------------------------------------------------

def moe_capacity(t_loc: int, k: int, E: int, capacity_factor: float) -> int:
    """Per-expert slot capacity of the GShard dispatch: the TRUE ceiling
    ``ceil((t_loc*k/E) * capacity_factor)``.

    The former ``int(q + 1)`` overshot by one whole slot per expert
    whenever the product was exactly integral (e.g. ``t_loc=64, k=2, E=8,
    factor=1.0`` gave 17 instead of 16 — a 6% buffer and wire overhead for
    nothing).  The quotient is rounded at 1e-9 before the ceiling so
    binary float dust (``0.1 * 3``-style) cannot bump an exact product to
    the next slot.
    """
    q = (t_loc * k / E) * capacity_factor
    return max(int(math.ceil(round(q, 9))), 1)


def moe_block(x, lp, cfg: ModelConfig, ctx: ParallelCtx):
    """Top-k expert-parallel FFN (GShard-style capacity dispatch).

    EP layouts:
    * default    — experts sharded over "model" (E/tp per chip); expert
      weights keep a ZeRO-3 d-shard that is all-gathered at use;
    * expert2d   — experts sharded over ("model","data") (beyond-paper,
      DESIGN.md §Perf): each chip owns whole experts with full d/ff, the
      dispatch all-to-all runs over the combined EP group, and the
      per-microbatch weight gathers disappear.

    Regimes per call:
    * "a2a"        — tokens sliced over "model", one ompx_alltoall out and
      back (train / prefill);
    * "replicated" — few tokens (decode): dispatch replicated across the EP
      group (expert2d first all-gathers the data-sharded tokens), experts
      sliced, partial-combine psum;
    * "local"      — tp == 1 or E unshardable.

    Capacity = ceil((T_loc*k/E)*capacity_factor) (:func:`moe_capacity`);
    overflow drops (combine weights renormalized), with the drop count
    recorded into the context's ``dispatch_stats`` frame when one is open.
    ``ctx.dispatch_impl`` = ``"fused"``/``"host"`` swaps the a2a regime's
    collective for the dropless one-sided ring of
    :mod:`repro.kernels.moe_dispatch` (docs/PERF.md).
    """
    B, T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    tp = ctx.tp
    ep2d = ctx.expert2d and E % max(ctx.ep_size, 1) == 0 and ctx.ep_size > 1
    ep = ctx.ep_size if ep2d else tp
    E_loc = E // ep if (E % ep == 0 and ep > 1) else E
    if E % ep == 0 and ep > 1 and (B * T) % tp == 0 and B * T >= tp:
        regime = "a2a"
    elif E % ep == 0 and ep > 1:
        regime = "replicated"
    else:
        regime = "local"
        E_loc = E

    flat = x.reshape(B * T, d)
    toks_local = flat                     # shared-expert input (my tokens)
    if regime == "a2a":
        t_loc = (B * T) // tp             # tokens sliced over "model" only
        t0 = lax.axis_index(ctx.tp_group.axes[0]) * t_loc
        toks = lax.dynamic_slice_in_dim(flat, t0, t_loc, axis=0)
    elif regime == "replicated" and ep2d and ctx.fsdp > 1:
        # decode: tokens are data-sharded; gather so dispatch is identical
        # across the combined EP group (tiny at decode: B*T tokens)
        toks = ompccl.allgather(flat, ctx.fsdp_group, axis=0,
                                invariant=ctx.inference)
        t_loc = B * T * ctx.fsdp
    else:
        toks, t_loc = flat, B * T

    router = lp["router"].astype(F32)                         # (d, E) replicated
    logits = jnp.dot(toks.astype(F32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, k)                        # (t_loc, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # dropless one-sided dispatch (kernels/moe_dispatch): opt-in via the
    # ParallelCtx knob, available whenever the a2a regime holds on a
    # single-axis EP group (the put ring); expert2d's two-axis group and
    # the replicated/local regimes fall through to the host paths below
    impl = "a2a"
    if regime == "a2a" and len(ctx.ep_group.axes) == 1:
        from repro.kernels.plan import resolve_dispatch_impl

        impl = resolve_dispatch_impl(getattr(ctx, "dispatch_impl", "auto"))
    if impl in ("fused", "host"):
        from repro.kernels.moe_dispatch.ops import moe_dispatch

        wg = gather_fsdp(lp["w_gate_e"], ctx, dim=1)          # (E_loc, d, ffm)
        wu = gather_fsdp(lp["w_up_e"], ctx, dim=1)
        wd = gather_fsdp(lp["w_down_e"], ctx, dim=2)          # (E_loc, ffm, d)
        combined = moe_dispatch(toks, top_e, top_w, wg, wu, wd,
                                ctx.ep_group, impl=impl)
        if "w_gate_s" in lp:  # shared experts (DeepSeek): full rows, then
            shared = mlp_block(  # my slice (see the host path below)
                toks_local, lp, ctx, names=("w_gate_s", "w_up_s", "w_down_s"))
            combined = combined + lax.dynamic_slice_in_dim(
                shared, t0, t_loc, axis=0)
        out = ompccl.allgather(combined, ctx.tp_group, axis=0,
                               invariant=ctx.inference)
        return out.reshape(B, T, d)

    cap = max(moe_capacity(t_loc, k, E, cfg.capacity_factor), 4)

    # slot assignment: position of each (token, choice) within its expert
    e_flat = top_e.reshape(-1)                                # (t_loc*k,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)       # (t_loc*k, E)
    slot = (jnp.cumsum(onehot, axis=0) - 1) * onehot          # running index
    slot = slot.sum(-1)                                       # (t_loc*k,)
    keep = slot < cap
    addr = e_flat * cap + jnp.clip(slot, 0, cap - 1)

    # capacity overflow is a silent quality tax; surface it as a traced
    # aux stat when a DispatchStats frame is open (ctx.dispatch_stats —
    # the dropless moe_dispatch path above records identically zero)
    from repro.core.context import default_context

    dropped = jnp.sum(~keep).astype(F32)
    default_context().dispatch_stats.record(
        moe_dropped=dropped,
        moe_routed=dropped * 0 + keep.size)  # varying like dropped

    from repro.core.vma import zeros_varying

    buf = zeros_varying((E * cap, d), x.dtype, x)
    src = jnp.repeat(toks, k, axis=0)                         # (t_loc*k, d)
    buf = buf.at[jnp.where(keep, addr, E * cap - 1)].add(
        jnp.where(keep[:, None], src, 0.0).astype(x.dtype), mode="drop")

    if regime == "a2a":
        sendbuf = buf.reshape(ep, E_loc * cap, d)
        recv = ompccl.alltoall(sendbuf, ctx.ep_group,
                               split_axis=0, concat_axis=0)    # (ep, E_loc*cap, d)
        expert_in = recv.reshape(ep, E_loc, cap, d).transpose(1, 0, 2, 3)
        expert_in = expert_in.reshape(E_loc, ep * cap, d)
    elif regime == "replicated":
        # dispatch is replicated across the EP group; slice my expert block
        off = ompccl.group_rank(ctx.ep_group) * E_loc * cap
        expert_in = lax.dynamic_slice_in_dim(
            buf, off, E_loc * cap, axis=0).reshape(E_loc, cap, d)
    else:
        expert_in = buf.reshape(E_loc, cap, d)

    if ep2d:
        # expert2d: weights already hold full d/ff — no ZeRO-3 gather
        wg, wu, wd = lp["w_gate_e"], lp["w_up_e"], lp["w_down_e"]
    else:
        wg = gather_fsdp(lp["w_gate_e"], ctx, dim=1)          # (E_loc, d, ffm)
        wu = gather_fsdp(lp["w_up_e"], ctx, dim=1)
        wd = gather_fsdp(lp["w_down_e"], ctx, dim=2)          # (E_loc, ffm, d)
    h = jnp.einsum("ecd,edf->ecf", expert_in, wg)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", expert_in, wu)
    out_e = jnp.einsum("ecf,efd->ecd", h, wd).astype(x.dtype)

    gates = (keep[:, None] * top_w.reshape(-1)[:, None]).astype(x.dtype)
    if regime == "a2a":
        back = out_e.reshape(E_loc, ep, cap, d).transpose(1, 0, 2, 3)
        back = back.reshape(ep, E_loc * cap, d)
        ret = ompccl.alltoall(back, ctx.ep_group, split_axis=0, concat_axis=0)
        ret = ret.reshape(E * cap, d)
        picked = ret[addr] * gates
        combined = picked.reshape(t_loc, k, d).sum(axis=1)
    elif regime == "replicated":
        # partial combine: only my experts contribute; psum over the group
        off = ompccl.group_rank(ctx.ep_group) * E_loc * cap
        local = addr - off
        mine = (local >= 0) & (local < E_loc * cap)
        ret_me = out_e.reshape(E_loc * cap, d)
        picked = jnp.where(
            mine[:, None],
            ret_me[jnp.clip(local, 0, E_loc * cap - 1)], 0.0).astype(x.dtype)
        combined = (picked * gates).reshape(t_loc, k, d).sum(axis=1)
        combined = ompccl.allreduce(combined, ctx.ep_group)
        if ep2d and ctx.fsdp > 1:   # back to my data-shard's rows
            r0 = lax.axis_index(ctx.fsdp_group.axes[0]) * (B * T)
            combined = lax.dynamic_slice_in_dim(combined, r0, B * T, axis=0)
    else:
        ret = out_e.reshape(E * cap, d)
        picked = ret[addr] * gates
        combined = picked.reshape(t_loc, k, d).sum(axis=1)

    if "w_gate_s" in lp:  # shared experts (DeepSeek)
        # the TP col->row shared MLP needs the SAME rows on every "model"
        # rank (its row-parallel psum sums feature partials per row), so it
        # runs on the full replicated token set; the a2a regime then takes
        # this rank's slice.  Feeding the a2a path's per-rank token slice
        # in directly would psum partials of DIFFERENT tokens together.
        shared = mlp_block(toks_local, lp, ctx,
                           names=("w_gate_s", "w_up_s", "w_down_s"))
        if regime == "a2a":
            shared = lax.dynamic_slice_in_dim(shared, t0, t_loc, axis=0)
        combined = combined + shared

    if regime == "a2a":
        out = ompccl.allgather(combined, ctx.tp_group, axis=0,
                               invariant=ctx.inference)  # tokens back
    else:
        out = combined
    return out.reshape(B, T, d)

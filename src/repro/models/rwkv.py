"""RWKV6 "Finch" — attention-free LM with data-dependent decay.

Manual-SPMD layout: heads (d / rwkv_head_dim) sharded over "model"; the
d→d projections are Megatron column shards; channel-mix is column→row with
an explicit reduce; per-channel decay/bonus vectors live in projection
output space so they shard with the heads.  The WKV recurrence runs through
the unified :mod:`repro.kernels.linear_scan` (chunked Pallas kernel on TPU,
jnp scan oracle elsewhere): state S_t = diag(w_t)·S_{t-1} + k_tᵀv_t, readout
r_t·(S_{t-1} + diag(u)·k_tᵀv_t).

Simplification vs the full Finch release (recorded in DESIGN.md): the five
token-shift mix coefficients are static (no per-token LoRA on the mu's);
the decay LoRA (w0 + tanh(x·A)·B) is kept — it is the paper's headline
"data-dependent decay".

Decode state per layer: token-shift carries (x_tm, x_cm) and the WKV state
(B, H_loc, hd, hd) — O(1) in sequence length, which is why rwkv6 runs the
long_500k shape natively.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ompccl
from repro.core.vma import zeros_varying
from repro.kernels.linear_scan.ops import linear_scan
from .config import ModelConfig, ParallelCtx
from .layers import (F32, ce_loss, col_matmul, embed_lookup, gather_fsdp,
                     layernorm, rmsnorm, row_matmul, tp_allreduce)

__all__ = ["rwkv_forward", "rwkv_loss", "rwkv_init_state", "rwkv_decode"]


def _token_shift(x, prev_last):
    """x_{t-1} along T; position 0 uses prev_last (B, d) (zeros at start)."""
    shifted = jnp.concatenate([prev_last[:, None, :], x[:, :-1]], axis=1)
    return shifted


def _per_head_norm(y, scale_loc, eps):
    """GroupNorm(H) analogue: layernorm within each head's hd channels."""
    yf = y.astype(F32)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    out = (yf - mu) * lax.rsqrt(var + eps)
    B, T, H_loc, hd = y.shape
    return (out * scale_loc.reshape(H_loc, hd).astype(F32)).astype(y.dtype)


def rwkv_block(x, lp, cfg: ModelConfig, ctx: ParallelCtx,
               state: Optional[dict] = None, *, scan_impl: str = "ref"):
    """One RWKV6 block.  Returns (x', new_state)."""
    B, T, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    H_loc = H // ctx.tp
    d_loc = d // ctx.tp

    # ---- time mix -----------------------------------------------------------
    xs = layernorm(x, lp["ln1"], cfg.norm_eps)
    prev = state["x_tm"] if state is not None else zeros_varying(
        (B, d), xs.dtype, xs)
    shifted = _token_shift(xs, prev)
    mu = lp["tm_mu"].astype(F32)                       # (5, d)
    delta = shifted.astype(F32) - xs.astype(F32)
    mix = lambda j: (xs.astype(F32) + mu[j] * delta).astype(x.dtype)
    xr, xk, xv, xw, xg = mix(0), mix(1), mix(2), mix(3), mix(4)

    r = col_matmul(xr, lp["tm_wr"], ctx)               # (B, T, d_loc)
    k = col_matmul(xk, lp["tm_wk"], ctx)
    v = col_matmul(xv, lp["tm_wv"], ctx)
    g = jax.nn.silu(col_matmul(xg, lp["tm_wg"], ctx).astype(F32))

    # data-dependent decay (LoRA): w = exp(-exp(w0 + tanh(xw A) B))
    low = jnp.tanh(jnp.dot(xw.astype(F32), lp["tm_wA"].astype(F32)))
    w_log = lp["tm_w0"].astype(F32) + jnp.dot(low, lp["tm_wB"].astype(F32))
    w = jnp.exp(-jnp.exp(w_log))                       # (B, T, d_loc) in (0,1)

    def heads(t):  # (B, T, d_loc) -> (B*H_loc, T, hd)
        return t.reshape(B, T, H_loc, hd).transpose(0, 2, 1, 3).reshape(
            B * H_loc, T, hd)

    s0 = state["S"].reshape(B * H_loc, hd, hd) if state is not None else None
    y, s_fin = linear_scan(
        heads(v.astype(F32)), heads(k.astype(F32)), heads(w),
        heads(r.astype(F32)), s0, readout_pre=True,
        impl=scan_impl if state is None else "ref")
    # diag(u) bonus: y_t += v_t * sum_n(r_t u k_t)
    u = lp["tm_u"].astype(F32).reshape(H_loc, hd)
    rk = (r.astype(F32) * k.astype(F32)).reshape(B, T, H_loc, hd)
    bonus = (rk * u).sum(-1)                           # (B, T, H_loc)
    y = y.reshape(B, H_loc, T, hd).transpose(0, 2, 1, 3)
    y = y + bonus[..., None] * v.astype(F32).reshape(B, T, H_loc, hd)

    y = _per_head_norm(y.astype(x.dtype), lp["tm_lnx"], cfg.norm_eps)
    y = (y.reshape(B, T, d_loc).astype(F32) * g).astype(x.dtype)
    x = x + row_matmul(y, lp["tm_wo"], ctx)

    # ---- channel mix ----------------------------------------------------------
    xs2 = layernorm(x, lp["ln2"], cfg.norm_eps)
    prev2 = state["x_cm"] if state is not None else zeros_varying(
        (B, d), xs2.dtype, xs2)
    shifted2 = _token_shift(xs2, prev2)
    cmu = lp["cm_mu"].astype(F32)                      # (2, d)
    xk2 = (xs2.astype(F32) + cmu[0] * (shifted2.astype(F32) - xs2.astype(F32))
           ).astype(x.dtype)
    xr2 = (xs2.astype(F32) + cmu[1] * (shifted2.astype(F32) - xs2.astype(F32))
           ).astype(x.dtype)
    kk = col_matmul(xk2, lp["cm_wk"], ctx).astype(F32)
    kk = jnp.square(jax.nn.relu(kk)).astype(x.dtype)
    vv = row_matmul(kk, lp["cm_wv"], ctx)              # (B, T, d) full
    rr = jax.nn.sigmoid(col_matmul(xr2, lp["cm_wr"], ctx).astype(F32))
    if ctx.tp > 1:
        off = lax.axis_index(ctx.tp_group.axes[0]) * d_loc
        vv_loc = lax.dynamic_slice_in_dim(vv, off, d_loc, axis=-1)
        out2 = ompccl.allgather((rr * vv_loc.astype(F32)).astype(x.dtype),
                                ctx.tp_group, axis=2, invariant=ctx.inference)
    else:
        out2 = (rr * vv.astype(F32)).astype(x.dtype)
    x = x + out2

    new_state = None
    if state is not None:
        new_state = {
            "x_tm": xs[:, -1, :],
            "x_cm": xs2[:, -1, :],
            "S": s_fin.reshape(B, H_loc, hd, hd),
        }
    return x, new_state


def rwkv_forward(params, tokens, cfg: ModelConfig, ctx: ParallelCtx,
                 state: Optional[dict] = None, *, scan_impl: str = "ref"):
    """Returns (hidden (B, T, d), new_state or None).

    ``state`` (stacked per layer) enables chunked prefill / decode; None for
    training.
    """
    x = embed_lookup(tokens, params["embed/table"], cfg, ctx)
    x = layernorm(x, params["embed_norm"], cfg.norm_eps)
    L = cfg.num_layers
    plen = len("layers/")
    stack = {k[plen:]: v for k, v in params.items() if k.startswith("layers/")}

    from repro.core.compat import typeof

    in_vma = getattr(typeof(x), "vma", frozenset())
    axes = set(in_vma)
    if not ctx.inference:
        if ctx.tp > 1:
            axes.add("model")
        if ctx.fsdp > 1:
            axes.add("data")
    carry_axes = tuple(a for a in ctx.world.lax_axes if a in axes)

    def body(carry, xs):
        h = carry
        if state is None:
            lp, st = xs, None
        else:
            lp, st = xs
        h2, st2 = rwkv_block(h, lp, cfg, ctx, st, scan_impl=scan_impl)
        h2 = ompccl.ensure_varying(h2, carry_axes)
        if st2 is None:
            st2 = 0.0  # placeholder ys
        return h2, st2

    if ctx.remat and state is None:
        body = jax.checkpoint(body)
    xs = stack if state is None else (stack, state)
    x = ompccl.ensure_varying(x, carry_axes)
    x, new_states = lax.scan(body, x, xs)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, (new_states if state is not None else None)


def rwkv_loss(params, batch, cfg: ModelConfig, ctx: ParallelCtx):
    h, _ = rwkv_forward(params, batch["tokens"], cfg, ctx)
    return ce_loss(h[:, :-1], params["lm_head"], batch["tokens"][:, 1:],
                   cfg, ctx)


def rwkv_init_state(cfg: ModelConfig, ctx: ParallelCtx, B_loc: int,
                    dtype=jnp.bfloat16):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H_loc = d // hd // ctx.tp
    L = cfg.num_layers
    return {
        "x_tm": jnp.zeros((L, B_loc, d), dtype),
        "x_cm": jnp.zeros((L, B_loc, d), dtype),
        "S": jnp.zeros((L, B_loc, H_loc, hd, hd), jnp.float32),
    }


def rwkv_decode(params, tokens, cfg, ctx, state):
    """One decode step (B, 1) -> (local logits, new state)."""
    h, state = rwkv_forward(params, tokens, cfg, ctx, state)
    logits = jnp.dot(h.astype(F32), params["lm_head"].astype(F32))
    return logits, state

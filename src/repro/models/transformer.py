"""Transformer forward passes (dense / MoE / MLA / VLM / audio encoder).

All functions run inside shard_map (manual SPMD).  Layer stacks are scanned
(``lax.scan`` over the leading L dim of every stacked param) with optional
remat; heterogeneous stacks (DeepSeek's leading dense layers, the MTP head)
are separate scans.

Caches are dicts of stacked arrays: {"k": (L, B, S, KH_loc, D), "v": …,
"pos": ()} so the decode scan threads per-layer slices.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ompccl
from .config import ModelConfig, ParallelCtx
from .layers import (
    KVCache, MLACache, attention_block, ce_loss, embed_lookup, gelu_mlp_block,
    layernorm, mla_block, mlp_block, moe_block, rmsnorm, row_matmul,
    col_matmul, gather_fsdp, tp_allreduce,
)
from .schema import head_parallel, kv_sharded

__all__ = [
    "transformer_forward", "transformer_loss", "init_cache",
    "transformer_prefill", "transformer_chunk_prefill", "transformer_decode",
]


def _stacked(params: Dict[str, jax.Array], prefix: str) -> Dict[str, jax.Array]:
    plen = len(prefix) + 1
    return {k[plen:]: v for k, v in params.items() if k.startswith(prefix + "/")}


def _sinusoid(T: int, d: int, dtype):
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((T, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang[:, : d // 2]))
    return pe.astype(dtype)


def _layer_body(x, lp, cfg: ModelConfig, ctx: ParallelCtx, *,
                moe: bool, mla: bool, positions, prefix_len: int,
                cache=None, chunked: bool = False):
    """One decoder block: (attn + residual) then (ffn + residual)."""
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps, plus_one=(cfg.family == "vlm"))
    if mla:
        attn, new_cache = mla_block(h, lp, cfg, ctx, positions=positions,
                                    cache=cache, chunked=chunked)
    else:
        attn, new_cache = attention_block(
            h, lp, cfg, ctx, positions=positions, prefix_len=prefix_len,
            cache=cache, causal=cfg.causal, chunked=chunked)
    x = x + attn
    h = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps, plus_one=(cfg.family == "vlm"))
    if moe:
        ffn = moe_block(h, lp, cfg, ctx)
        # deepseek keeps no separate dense FFN on MoE layers (shared experts
        # are inside moe_block)
    elif cfg.family == "audio":
        ffn = gelu_mlp_block(h, lp, ctx)
    else:
        act = "gelu" if cfg.family == "vlm" else "silu"
        ffn = mlp_block(h, lp, ctx, act=act)
    return x + ffn, new_cache


def _scan_stack(x, stack, cfg, ctx, *, moe, mla, positions, prefix_len,
                caches=None, remat=False, chunked=False):
    """Scan a homogeneous layer stack; threads caches if given.

    The carry is normalized to a canonical varying set (vma bookkeeping):
    different layer kinds leave the residual stream with different inferred
    replication (a psum'd dense output is model-invariant, an all-gathered
    MoE output is not), and scan requires a fixed carry type.  Canonical set:
    the input's own varying axes, plus "model" in training (AD-friendly
    gathers are Varying->Varying); inference uses invariant gathers so the
    residual stream stays exactly as replicated as it really is.
    """
    from repro.core.compat import typeof
    from repro.core.context import default_context
    from repro.core.ompccl import ensure_varying

    in_vma = getattr(typeof(x), "vma", frozenset())
    axes = set(in_vma)
    if not ctx.inference:
        if ctx.tp > 1:
            axes.add("model")       # train-mode TP gathers are Varying->Varying
        if ctx.fsdp > 1:
            axes.add("data")        # ZeRO-3 weight gathers (AD: reduce-scatter)
    world = tuple(a for a in ctx.world.lax_axes if a in axes)

    # dispatch stats recorded inside the scan body are tracers of the inner
    # (scan/remat) trace — they can't escape through the context's side
    # channel.  When a collection frame is open, re-thread them: collect
    # per-layer inside the body, return them as scan outputs, and re-record
    # the layer-summed totals into the outer frame after the scan.
    stats = default_context().dispatch_stats
    thread_stats = stats.active

    def body(carry, xs):
        h = carry
        if caches is None:
            lp = xs
            cache = None
        else:
            lp, cache = xs
        if thread_stats:
            with stats.collect() as ds:
                h2, new_cache = _layer_body(
                    h, lp, cfg, ctx, moe=moe, mla=mla, positions=positions,
                    prefix_len=prefix_len, cache=cache, chunked=chunked)
            aux = {k: ds[k] for k in sorted(ds)}
        else:
            h2, new_cache = _layer_body(
                h, lp, cfg, ctx, moe=moe, mla=mla, positions=positions,
                prefix_len=prefix_len, cache=cache, chunked=chunked)
            aux = {}
        return ensure_varying(h2, world), (new_cache, aux)

    if remat:
        body = jax.checkpoint(body)
    xs = stack if caches is None else (stack, caches)
    x, (new_caches, aux) = lax.scan(body, ensure_varying(x, world), xs)
    stats.record(**{k: jnp.sum(v) for k, v in aux.items()})
    return x, new_caches


def _make_layer_cache(cfg: ModelConfig, ctx: ParallelCtx, B: int, S: int, L: int,
                      *, seq_sharded: bool, dtype) -> Dict[str, jax.Array]:
    """Local cache shapes for one layer stack of depth L (stacked)."""
    if cfg.attention == "mla":
        return {
            "c": jnp.zeros((L, B, S, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((L, B, S, cfg.qk_rope_head_dim), dtype),
        }
    from .layers import local_kv_heads

    KH_loc = local_kv_heads(cfg, ctx)
    S_loc = S // ctx.fsdp if seq_sharded else S
    return {
        "k": jnp.zeros((L, B, S_loc, KH_loc, cfg.head_dim), dtype),
        "v": jnp.zeros((L, B, S_loc, KH_loc, cfg.head_dim), dtype),
    }


def init_cache(cfg: ModelConfig, ctx: ParallelCtx, B_loc: int, S: int,
               *, seq_sharded: bool = False, dtype=jnp.bfloat16):
    """Decode cache pytree (local shapes) + position scalar.

    ``seq_sharded`` is a *static* layout property: it must be passed again
    (identically) to transformer_forward / the serve step builder.
    """
    kd = cfg.first_k_dense if cfg.moe else 0
    cache = _make_layer_cache(cfg, ctx, B_loc, S, cfg.num_layers - kd,
                              seq_sharded=seq_sharded, dtype=dtype)
    if kd:
        dpfx = _make_layer_cache(cfg, ctx, B_loc, S, kd,
                                 seq_sharded=seq_sharded, dtype=dtype)
        cache["dense_c"] = dpfx["c"]
        cache["dense_kr"] = dpfx["kr"]
    cache["pos"] = jnp.zeros((), jnp.int32)
    return cache


def _wrap_cache(cfg, raw, pos, seq_sharded, L):
    """Build the scan-ready cache object: pos broadcast to (L, ...) so every
    leaf has a leading layer dim for lax.scan (pos may be scalar or (B,))."""
    pos_l = jnp.broadcast_to(pos, (L,) + jnp.shape(pos))
    if cfg.attention == "mla":
        return MLACache(raw["c"], raw["kr"], pos_l)
    return KVCache(raw["k"], raw["v"], pos_l, seq_sharded=seq_sharded)


def _unwrap_cache(cfg, cache_obj):
    if cfg.attention == "mla":
        return {"c": cache_obj.c, "kr": cache_obj.kr}, cache_obj.pos[0]
    return {"k": cache_obj.k, "v": cache_obj.v}, cache_obj.pos[0]


def transformer_forward(
    params: Dict[str, jax.Array],
    tokens,                      # (B, T) int32 — token ids
    cfg: ModelConfig,
    ctx: ParallelCtx,
    *,
    prefix_embeds=None,          # (B, P, d) — VLM patch / audio frame stubs
    embeds=None,                 # (B, T, d) — direct embedding input (audio)
    cache: Optional[dict] = None,
    positions=None,
    seq_sharded: bool = False,
    chunked: bool = False,
):
    """Returns (hidden (B, T_total, d), new_cache or None)."""
    if embeds is not None:
        x = embeds
    else:
        x = embed_lookup(tokens, params["embed/table"], cfg, ctx)
        if cfg.family == "vlm":
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    prefix_len = 0
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = prefix_embeds.shape[1]
    if "embed_norm" in params:
        x = layernorm(x, params["embed_norm"], cfg.norm_eps)
    if cfg.family == "audio":
        x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]

    T = x.shape[1]
    if positions is None:
        positions = jnp.arange(T)

    pos = cache["pos"] if cache is not None else None
    new_pos = pos
    remat = ctx.remat and cache is None
    kd = cfg.first_k_dense if cfg.moe else 0

    if kd:
        dstack = _stacked(params, "dense_layers")
        dcaches = None
        if cache is not None:
            dcaches = _wrap_cache(cfg, {"c": cache["dense_c"],
                                        "kr": cache["dense_kr"]}, pos, False, kd)
        x, new_d = _scan_stack(
            x, dstack, cfg, ctx, moe=False, mla=cfg.attention == "mla",
            positions=positions, prefix_len=prefix_len, caches=dcaches,
            remat=remat, chunked=chunked)
    stack = _stacked(params, "layers")
    caches = None
    if cache is not None:
        raw = {k: v for k, v in cache.items()
               if k in ("k", "v", "c", "kr")}
        caches = _wrap_cache(cfg, raw, pos, seq_sharded,
                             cfg.num_layers - kd)
    x, new_caches = _scan_stack(
        x, stack, cfg, ctx, moe=cfg.moe, mla=cfg.attention == "mla",
        positions=positions, prefix_len=prefix_len, caches=caches,
        remat=remat, chunked=chunked)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps,
                plus_one=(cfg.family == "vlm"))

    new_cache = None
    if cache is not None:
        raw, new_pos = _unwrap_cache(cfg, new_caches)
        new_cache = dict(raw)
        new_cache["pos"] = new_pos
        if kd:
            draw, _ = _unwrap_cache(cfg, new_d)
            new_cache["dense_c"] = draw["c"]
            new_cache["dense_kr"] = draw["kr"]
    return x, new_cache


def _lm_head(params, cfg):
    if cfg.family == "vlm":          # tied embeddings
        return params["embed/table"].T
    return params["lm_head"]


def transformer_loss(params, batch, cfg: ModelConfig, ctx: ParallelCtx):
    """Next-token CE (LM) or masked-frame CE (audio).  Scalar f32 loss."""
    if cfg.family == "audio":
        h, _ = transformer_forward(params, None, cfg, ctx,
                                   embeds=batch["embeds"])
        head = gather_fsdp(params["head"], ctx, dim=0)      # (d, V) replicated V
        loss = ce_loss(h, head, batch["targets"], cfg, ctx,
                       weights=batch.get("mask"))
        return loss
    prefix_embeds = batch.get("prefix_embeds")
    h, _ = transformer_forward(params, batch["tokens"], cfg, ctx,
                               prefix_embeds=prefix_embeds)
    if prefix_embeds is not None:
        h = h[:, prefix_embeds.shape[1]:]
    loss = ce_loss(h[:, :-1], _lm_head(params, cfg), batch["tokens"][:, 1:],
                   cfg, ctx)
    if cfg.mtp:  # DeepSeek multi-token prediction auxiliary head
        emb_next = embed_lookup(batch["tokens"][:, 1:], params["embed/table"],
                                cfg, ctx)
        hm = rmsnorm(h[:, :-1], params["mtp/norm_h"], cfg.norm_eps)
        em = rmsnorm(emb_next, params["mtp/norm_e"], cfg.norm_eps)
        z = jnp.concatenate([hm, em], axis=-1)
        z = jnp.dot(z, gather_fsdp(params["mtp/proj"], ctx, dim=0),
                    preferred_element_type=jnp.float32).astype(h.dtype)
        mt_stack = _stacked(params, "mtp/layer")
        z, _ = _scan_stack(z, mt_stack, cfg, ctx, moe=False,
                           mla=cfg.attention == "mla",
                           positions=jnp.arange(z.shape[1]), prefix_len=0,
                           remat=ctx.remat)
        mtp_loss = ce_loss(z[:, :-1], _lm_head(params, cfg),
                           batch["tokens"][:, 2:], cfg, ctx)
        loss = loss + 0.1 * mtp_loss
    return loss


def transformer_prefill(params, tokens, cfg, ctx, cache, *,
                        prefix_embeds=None, seq_sharded: bool = False):
    """Fill the cache from a prompt; returns (last-position logits, cache)."""
    h, cache = transformer_forward(params, tokens, cfg, ctx, cache=cache,
                                   prefix_embeds=prefix_embeds,
                                   seq_sharded=seq_sharded)
    logits = jnp.dot(h[:, -1:].astype(jnp.float32),
                     _lm_head(params, cfg).astype(jnp.float32))
    return logits, cache


def transformer_chunk_prefill(params, tokens, cfg, ctx, cache, rlen, *,
                              seq_sharded: bool = False):
    """One chunked-prefill step: append ``tokens`` (B, C) at ``cache['pos']``.

    The serving engine streams a prompt through the cache in fixed-size
    chunks (docs/SERVING.md): each call writes C new K/V rows at the running
    position and attends the chunk's queries over the whole valid prefix.
    ``rlen`` (traced scalar, 1 <= rlen <= C) is the number of REAL tokens in
    the chunk; the tail is padding whose cache rows are overwritten by the
    next chunk / decode write before any query can attend to them (causal
    masking keeps them invisible meanwhile).  Returns the logits at the last
    real position and the cache with ``pos`` advanced by ``rlen``.
    """
    if seq_sharded:
        raise ValueError("chunked prefill does not support seq_sharded caches")
    C = tokens.shape[1]
    p0 = cache["pos"]
    positions = p0 + jnp.arange(C)
    h, cache = transformer_forward(params, tokens, cfg, ctx, cache=cache,
                                   positions=positions, chunked=True)
    last = lax.dynamic_slice_in_dim(h, jnp.maximum(rlen - 1, 0), 1, axis=1)
    logits = jnp.dot(last.astype(jnp.float32),
                     _lm_head(params, cfg).astype(jnp.float32))
    # the layer scan advanced pos by the full (possibly padded) chunk width;
    # the true advance is the real token count
    cache["pos"] = p0 + rlen
    return logits, cache


def transformer_decode(params, tokens, cfg, ctx, cache, *,
                       seq_sharded: bool = False):
    """One decode step: tokens (B, 1) -> (local logits (B, 1, V/tp), cache).

    cache["pos"] may be a scalar (uniform batch) or (B,) per-slot positions
    (continuous batching).
    """
    pos = cache["pos"]
    positions = (pos[:, None] if jnp.ndim(pos) == 1
                 else jnp.full((1,), pos, jnp.int32))
    h, cache = transformer_forward(
        params, tokens, cfg, ctx, cache=cache,
        positions=positions, seq_sharded=seq_sharded)
    logits = jnp.dot(h.astype(jnp.float32),
                     _lm_head(params, cfg).astype(jnp.float32))
    return logits, cache

"""Model + parallelism configuration shared by every architecture."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.core.groups import DiompGroup
from repro.distributed.buckets import DEFAULT_BUCKET_BYTES

__all__ = ["ModelConfig", "ParallelCtx"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Field names follow the assignment table."""

    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int               # 0 for attention-free archs
    kv_heads: int = 0
    head_dim: int = 0            # derived if 0: d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0

    # attention flavor
    attention: str = "gqa"       # gqa | mla | none
    causal: bool = True          # False for encoder-only
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0   # partial rotary (stablelm/glm)

    # MoE
    moe: bool = False
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    shared_experts: int = 0
    first_k_dense: int = 0       # deepseek: leading dense layers
    capacity_factor: float = 1.25
    mtp: bool = False            # deepseek multi-token prediction head

    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM / RWKV / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    conv_width: int = 4
    attn_every: int = 0          # zamba2: shared attn block period
    rwkv_head_dim: int = 64

    # VLM / audio frontends are STUBS: input_specs() hands pre-computed
    # patch/frame embeddings of this many prefix positions.
    prefix_tokens: int = 0

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived sizes ---------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameters (exact, from the schema)."""
        from . import schema  # local import to avoid cycle

        total = 0
        for s in schema.build_schema(self).values():
            n = 1
            for d in s.shape:
                n *= int(d)
            total += n
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        from . import schema

        total = 0
        for s in schema.build_schema(self).values():
            n = 1
            for d in s.shape:
                n *= d
            if s.per_expert:
                n = n // max(self.num_experts, 1) * (
                    self.experts_per_token + self.shared_experts
                )
            total += n
        return total


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Static parallel layout for one mesh — sizes + DiOMP group handles.

    Built once per (mesh, config); passed into the shard_map'd step so every
    layer knows its local tile sizes *statically* and which group each
    collective targets.
    """

    tp: int                       # size of the "model" axis
    fsdp: int                     # size of the "data" axis (ZeRO-3 shard)
    dp: int                       # total data parallel = pod * data
    pods: int
    tp_group: DiompGroup
    fsdp_group: DiompGroup
    dp_group: DiompGroup
    ep_group: DiompGroup
    world: DiompGroup
    pod_group: Optional[DiompGroup] = None

    # knobs (the §Perf hillclimb surface)
    dp_backend: str = "hierarchical"   # flat | hierarchical
    grad_codec: str = "none"           # none | int8 | topk
    bucket_bytes: int = DEFAULT_BUCKET_BYTES  # DP grad bucket size; grads
    #                                    are packed into flat f32 buckets of
    #                                    this many bytes per (group, dtype,
    #                                    dup) partition and reduced whole-
    #                                    bucket through one communicator
    #                                    handle.  0 disables bucketing (the
    #                                    per-param baseline path).
    overlap_grad_reduce: bool = True   # reduce-scatter bucket partial sums
    #                                    inside the microbatch accumulation
    #                                    scan (carry holds 1/|group| shards),
    #                                    one invariant all-gather per bucket
    #                                    after the scan; requires bucketing,
    #                                    microbatch > 1 and grad_codec="none"
    use_ring_matmul: bool = False      # Cannon-style TP matmul overlap
    ring_impl: str = "auto"            # auto | fused (bidirectional, planner-
    #                                    scheduled) | host (unidirectional XLA-
    #                                    overlap loop); resolved by the step
    #                                    builders via plan.resolve_ring_impl
    dispatch_impl: str = "auto"        # MoE dispatch: auto (-> a2a, the host
    #                                    collective capacity path) | a2a |
    #                                    fused (dropless one-sided ring,
    #                                    combine overlapped under the expert
    #                                    GEMMs) | host (same puts serialized);
    #                                    resolved by the step builders via
    #                                    plan.resolve_dispatch_impl.  The
    #                                    dropless modes are opt-in: they keep
    #                                    tokens the capacity path would drop,
    #                                    so they change the numbers.
    seq_parallel: str = "auto"         # self-attention context strategy:
    #                                    auto (-> allgather) | allgather
    #                                    (materialize full K/V per rank, one
    #                                    bulk collective) | ring (fused ring
    #                                    attention: K/V stripes rotate as
    #                                    one-sided puts folded with the
    #                                    online-softmax merge, O(T/n) memory);
    #                                    resolved by the step builders via
    #                                    plan.resolve_seq_parallel
    remat: bool = True
    microbatch: int = 1                # grad-accumulation factor
    seq_shard: bool = False            # sequence parallelism for norms/residual
    explicit_dp: bool = True           # DP reduction through OMPCCL (DiOMP)
    #                                    vs XLA-implicit (the MPI+X baseline)
    inference: bool = False            # serve steps: no AD; gathers use the
    #                                    invariant all-gather (exact vma typing)
    expert2d: bool = False             # MoE experts sharded over model x data
    #                                    (combined-group a2a; no d-gathers)
    fsdp_params: bool = True           # False (inference): dense weights stay
    #                                    TP-sharded only — no ZeRO-3 gathers
    gather_codec: str = "none"         # "int8": quantize ZeRO-3 weight
    #                                    gathers (2x wire; straight-through
    #                                    estimator keeps grads flowing)
    layout: str = "tp"                 # "tp" (default) | "dp_only" (no TP:
    #                                    batch over every axis; small models)

    @classmethod
    def from_mesh(cls, mesh: Mesh, **knobs) -> "ParallelCtx":
        from repro.core.groups import standard_groups

        g = standard_groups(mesh)
        shape = dict(mesh.shape)
        tp = shape.get("model", 1)
        fsdp = shape.get("data", 1)
        pods = shape.get("pod", 1)
        if knobs.get("layout") == "dp_only":
            # no TP: the model axis joins the data-parallel domain
            dp_axes = tuple(a for a in ("pod", "data", "model")
                            if a in shape)
            return cls(
                tp=1,
                fsdp=fsdp,
                dp=fsdp * pods * tp,
                pods=pods,
                tp_group=DiompGroup((), name="self"),
                fsdp_group=g.get("dp_inner",
                                 DiompGroup(("data",), name="dp_inner")),
                dp_group=DiompGroup(dp_axes, name="dp_all"),
                ep_group=DiompGroup((), name="self"),
                world=g["world"],
                pod_group=g.get("pod"),
                **knobs,
            )
        if knobs.get("expert2d"):
            knobs = dict(knobs)
            knobs["ep_group"] = DiompGroup(("model", "data"), name="ep2d")
            return cls(
                tp=tp, fsdp=fsdp, dp=fsdp * pods, pods=pods,
                tp_group=g.get("tp", DiompGroup(("model",), name="tp")),
                fsdp_group=g.get("dp_inner",
                                 DiompGroup(("data",), name="dp_inner")),
                dp_group=g["dp"],
                world=g["world"],
                pod_group=g.get("pod"),
                **knobs,
            )
        return cls(
            tp=tp,
            fsdp=fsdp,
            dp=fsdp * pods,
            pods=pods,
            tp_group=g.get("tp", DiompGroup(("model",), name="tp")),
            fsdp_group=g.get("dp_inner", DiompGroup(("data",), name="dp_inner")),
            dp_group=g["dp"],
            ep_group=g.get("ep", DiompGroup(("model",), name="ep")),
            world=g["world"],
            pod_group=g.get("pod"),
            **knobs,
        )

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return self.dp_group.axes

    @property
    def ep_size(self) -> int:
        n = 1
        from jax import lax  # static under trace: mesh sizes are known
        # group sizes are static: derive from the stored dp/tp/fsdp counts
        for ax in self.ep_group.axes:
            n *= {"model": self.tp, "data": self.fsdp,
                  "pod": self.pods}[ax]
        return n

    def local_heads(self, cfg: ModelConfig) -> int:
        assert cfg.num_heads % self.tp == 0, (cfg.num_heads, self.tp)
        return cfg.num_heads // self.tp

    def local_kv_heads(self, cfg: ModelConfig) -> int:
        """KV heads per device; GQA groups with kv < tp replicate."""
        return max(1, cfg.kv_heads // self.tp)

    def kv_shard(self, cfg: ModelConfig) -> int:
        """How many ways the kv heads are actually sharded (≤ tp)."""
        return min(cfg.kv_heads, self.tp) if cfg.kv_heads else 1

"""Minimod — the paper's flagship application as a real driver (§4.5).

The seed kept Minimod as a host-loop example: 1-D symmetric Z sharding,
halo exchange outside the kernel, no overlap.  This driver is the full
vertical slice:

* **2-D (Z×Y) domain decomposition** with **asymmetric** Z extents —
  heterogeneous ranks own subdomains proportional to their ``weights``
  (the paper's asymmetric-allocation scenario); the wavefield regions are
  registered through :meth:`~repro.core.pgas.GlobalMemory.alloc_asymmetric`
  so the PGAS mapping table carries the real per-rank byte plan.
* **Three execution modes** (the benchmark sweep):

  - ``none``  — two-sided MPI-shaped exchange (paper Listing 2: gather the
    slabs, select, barrier), compute strictly after;
  - ``host``  — one-sided puts + one fence (paper Listing 1), full-grid
    compute after the fence — overlap left to the XLA scheduler;
  - ``fused`` — the halo-overlapped step of
    :mod:`repro.kernels.stencil.fused`: carried halos, boundary computed
    first and put one-sided while the interior runs under the exchange,
    per-step neighbor fence, schedule from
    :meth:`~repro.kernels.plan.OverlapPlanner.plan_halo_slots`.

* **Audit trail**: every one-sided put is recorded both on the OMPCCL
  communicator byte log and on the RMATracker's halo windows; the result
  carries both so callers can assert exact put-traffic parity.

SPMD note: asymmetric extents are realized as max-extent shards with a
static ``z_extents`` tuple marking the valid rows (invalid rows pinned to
zero); :func:`pad_shards`/:func:`unpad_shards` convert between the logical
grid and the padded device layout.
"""

from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import ompccl, rma
from repro.core.compat import axis_size, make_mesh, shard_map
from repro.core.coordination import fetch_global
from repro.core.context import DiompContext, use_default
from repro.core.groups import DiompGroup
from repro.kernels.plan import HaloPlan, default_planner, split_extents
from repro.kernels.stencil.fused import (Halos, exchange_halos,
                                         fused_wave_step)
from repro.kernels.stencil.ref import RADIUS, wave_step_ref
from repro.launch.shapes import STENCIL_SHAPES, StencilShape

__all__ = [
    "MODES",
    "MinimodResult",
    "pad_shards",
    "run_minimod",
    "split_extents",
    "unpad_shards",
]

MODES = ("none", "host", "fused")


# ---------------------------------------------------------------------------
# decomposition
# ---------------------------------------------------------------------------


# split_extents lives in repro.kernels.plan (it now also sizes the MoE
# dispatch planner's per-expert capacities); re-exported here unchanged so
# the driver API and existing imports keep working.


def pad_shards(a: np.ndarray, z_extents: Sequence[int]) -> np.ndarray:
    """(Z, Y, X) logical grid -> (nz·zmax, Y, X) padded device layout."""
    zmax = max(z_extents)
    blocks, off = [], 0
    for e in z_extents:
        blocks.append(np.pad(a[off:off + e], ((0, zmax - e), (0, 0), (0, 0))))
        off += e
    return np.concatenate(blocks, axis=0)


def unpad_shards(a: np.ndarray, z_extents: Sequence[int]) -> np.ndarray:
    """Inverse of :func:`pad_shards`: drop every rank's padding rows."""
    zmax = max(z_extents)
    return np.concatenate(
        [a[i * zmax:i * zmax + e] for i, e in enumerate(z_extents)], axis=0)


# ---------------------------------------------------------------------------
# the two baseline halo styles (the paper's programmability comparison)
# ---------------------------------------------------------------------------


def _host_step_listing1(u, u_prev, c2dt2, zgroup, *, dx=1.0):
    """Minimod step, DiOMP style (paper Listing 1): two one-sided puts +
    one fence, then the full-grid stencil — exchange and compute strictly
    serialized (the ``host`` benchmark mode)."""
    R = RADIUS
    left, right = rma.halo_exchange(u, zgroup, halo=R, axis=0)
    up = jnp.concatenate([left, u, right], axis=0)
    prev = jnp.pad(u_prev, ((R, R), (0, 0), (0, 0)))
    return wave_step_ref(up, prev, c2dt2, dx=dx)[R:-R]


def _two_sided_halos(u, zgroup, *, zv):
    """MPI style (paper Listing 2): explicit sends, receives and Waitall —
    every slab materialized on every rank, then selected and barriered."""
    R = RADIUS
    Z, Y, X = u.shape
    n = axis_size(zgroup.axes[0])
    iz = lax.axis_index(zgroup.axes[0])
    down = lax.dynamic_slice(u, (zv - R, 0, 0), (R, Y, X))
    up_slab = lax.slice_in_dim(u, 0, R, axis=0)
    all_down = ompccl.allgather(down, zgroup, axis=0)
    all_up = ompccl.allgather(up_slab, zgroup, axis=0)
    left = lax.dynamic_slice_in_dim(
        all_down, lax.rem(iz + n - 1, n) * R, R, axis=0)
    right = lax.dynamic_slice_in_dim(
        all_up, lax.rem(iz + 1, n) * R, R, axis=0)
    left = jnp.where(iz == 0, jnp.zeros_like(left), left)
    right = jnp.where(iz == n - 1, jnp.zeros_like(right), right)
    token = ompccl.barrier_value(zgroup)        # MPI_Waitall
    wait = (0 * token).astype(u.dtype)
    return Halos(left + wait, right + wait, None, None)


def halo_loc() -> Dict[str, int]:
    """Lines of code of the two halo styles (the paper's Fig. 8 claim)."""
    one = len(inspect.getsource(_host_step_listing1).strip().splitlines())
    two = len(inspect.getsource(_two_sided_halos).strip().splitlines())
    return {"diomp": one, "two_sided": two}


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MinimodResult:
    """One Minimod run plus its audit trail."""

    field: np.ndarray                  # (Z, Y, X) logical wavefield
    wall_s: float
    mode: str
    grid: Tuple[int, int, int]
    steps: int
    nz: int
    ny: int
    z_extents: Tuple[int, ...]
    plan: HaloPlan
    # OMPCCL communicator log (trace-time: one entry per call site)
    puts: int
    put_bytes: int
    # RMATracker halo-window accounting
    tracker_puts: int
    tracker_put_bytes: int
    fences: int
    window_bytes: Dict[str, int]
    # PGAS plan of the wavefield regions
    region_sizes: Tuple[int, ...]
    alloc_counts: Dict[str, int]

    @property
    def energy(self) -> float:
        return float(np.square(self.field).sum())


def run_minimod(
    grid: Tuple[int, int, int] = (64, 64, 64),
    steps: Optional[int] = None,
    nz: int = 8,
    ny: int = 1,
    weights: Optional[Sequence[float]] = None,
    *,
    mode: str = "fused",
    dtype=jnp.float32,
    c2dt2: float = 0.1,
    dx: float = 1.0,
    interpret: Optional[bool] = None,
    shape: Optional[StencilShape] = None,
    u0: Optional[np.ndarray] = None,
    u_prev0: Optional[np.ndarray] = None,
) -> MinimodResult:
    """Run ``steps`` of Minimod on an (nz × ny) decomposition.

    ``shape`` (a :data:`~repro.launch.shapes.STENCIL_SHAPES` cell or name)
    overrides grid/steps/nz/ny/weights in one go.  The default initial
    condition is the point source at the grid center; pass ``u0``/
    ``u_prev0`` (logical (Z, Y, X) arrays) for custom fields.
    """
    if isinstance(shape, str):
        shape = STENCIL_SHAPES[shape]
    if shape is not None:
        grid = shape.grid
        steps = shape.steps if steps is None else steps
        nz, ny = shape.nz, shape.ny
        # an explicitly passed decomposition wins over the shape default
        weights = shape.weights if weights is None else weights
    steps = 10 if steps is None else steps
    if mode not in MODES:
        raise ValueError(f"mode {mode!r} not in {MODES}")
    Z, Y, X = grid
    if Y % ny:
        raise ValueError(f"Y={Y} not divisible by ny={ny} (Y is symmetric)")
    if mode == "none" and ny > 1:
        raise ValueError("the two-sided baseline is 1-D only (use ny=1)")
    z_extents = split_extents(Z, nz, weights, minimum=RADIUS)
    symmetric = len(set(z_extents)) == 1
    zmax = max(z_extents)
    y_loc = Y // ny

    mesh = make_mesh((nz, ny), ("z", "y"), axis_types="auto")
    ctx = DiompContext(mesh=mesh)
    with use_default(ctx):
        zg = DiompGroup(("z",), name="z")
        yg = DiompGroup(("y",), name="y") if ny > 1 else None
        grid_group = DiompGroup(("z", "y"), name="grid")

        # PGAS registration: heterogeneous ranks own proportional bytes —
        # rank (iz, iy) holds z_extents[iz]·y_loc·X cells, addressed through
        # the second-level pointer like every asymmetric region
        item = jnp.dtype(dtype).itemsize
        sizes = [z_extents[r // ny] * y_loc * X * item
                 for r in range(nz * ny)]
        handles = [
            ctx.memory.alloc_asymmetric(f"minimod.{nm}", sizes, grid_group,
                                        logical_axes=("z", "y", None),
                                        dtype=str(jnp.dtype(dtype)))
            for nm in ("u", "u_prev")
        ]
        region_sizes = tuple(handles[0].region.sizes)

        plan = default_planner().plan_halo_slots(
            zmax, y_loc, X, dtype, nz, ny=ny, halo=RADIUS)
        ext_arg = None if symmetric else tuple(z_extents)

        if u0 is None:
            u0 = np.zeros(grid, np.float64)
            u0[Z // 2, Y // 2, X // 2] = 1.0      # point source
        if u_prev0 is None:
            u_prev0 = np.zeros(grid, np.float64)
        u_in = pad_shards(np.asarray(u0, jnp.dtype(dtype)), z_extents)
        up_in = pad_shards(np.asarray(u_prev0, jnp.dtype(dtype)), z_extents)

        def fused_run(u, up):
            if plan.overlap:
                halos = exchange_halos(u, zg, yg, z_extents=ext_arg)

                def body(carry, _):
                    u, up, h = carry
                    un, hn = fused_wave_step(
                        u, up, c2dt2, zg, yg, dx=dx, plan=plan, halos=h,
                        z_extents=ext_arg, interpret=interpret,
                        return_halos=True)
                    return (un, u, hn), None

                (u, up, _), _ = lax.scan(body, (u, up, halos), None,
                                         length=steps)
            else:                 # degenerate grid: planner fell back
                def body(carry, _):
                    u, up = carry
                    un = fused_wave_step(
                        u, up, c2dt2, zg, yg, dx=dx, plan=plan,
                        z_extents=ext_arg, interpret=interpret)
                    return (un, u), None

                (u, up), _ = lax.scan(body, (u, up), None, length=steps)
            return u

        serial_plan = dataclasses.replace(plan, overlap=False)

        def host_run(u, up):
            def body(carry, _):
                u, up = carry
                if symmetric and ny == 1:     # the paper-verbatim listing
                    un = _host_step_listing1(u, up, c2dt2, zg, dx=dx)
                else:
                    un = fused_wave_step(
                        u, up, c2dt2, zg, yg, dx=dx, plan=serial_plan,
                        z_extents=ext_arg, interpret=interpret)
                return (un, u), None

            (u, up), _ = lax.scan(body, (u, up), None, length=steps)
            return u

        def none_run(u, up):
            iz = lax.axis_index("z")
            zv = zmax if ext_arg is None else \
                jnp.asarray(ext_arg, jnp.int32)[iz]

            def body(carry, _):
                u, up = carry
                halos = _two_sided_halos(u, zg, zv=zv)
                un = fused_wave_step(
                    u, up, c2dt2, zg, yg, dx=dx, plan=serial_plan,
                    halos=halos, z_extents=ext_arg, interpret=interpret)
                return (un, u), None

            (u, up), _ = lax.scan(body, (u, up), None, length=steps)
            return u

        run = {"fused": fused_run, "host": host_run, "none": none_run}[mode]
        # the plan the chosen mode actually executes: the serialized
        # baselines run the fallback schedule, never the overlapped one
        used_plan = plan if mode == "fused" else serial_plan
        f = jax.jit(shard_map(run, mesh=mesh,
                              in_specs=(P("z", "y"), P("z", "y")),
                              out_specs=P("z", "y")))
        t0 = time.perf_counter()
        out = jax.block_until_ready(f(u_in, up_in))
        wall = time.perf_counter() - t0

        for h in handles:
            ctx.memory.free(h)
        stats = ctx.stats()
        bstats = ctx.byte_stats()
        result = MinimodResult(
            field=unpad_shards(fetch_global(out), z_extents),
            wall_s=wall, mode=mode, grid=grid, steps=steps, nz=nz, ny=ny,
            z_extents=z_extents, plan=used_plan,
            puts=sum(ops.get("put", 0) for ops in stats.values()),
            put_bytes=sum(ops.get("put", 0) for ops in bstats.values()),
            tracker_puts=ctx.rma.puts,
            tracker_put_bytes=ctx.rma.put_bytes,
            fences=ctx.rma.fences,
            window_bytes=dict(ctx.rma.window_bytes),
            region_sizes=region_sizes,
            alloc_counts=dict(ctx.memory.alloc_counts),
        )
    return result

"""Application drivers — the paper's workloads promoted to real programs.

Unlike ``examples/`` (thin CLI demonstrations), an app owns its full
vertical slice: domain decomposition, PGAS region registration, the
schedule objects its kernels execute, and the audit trail (OMPCCL call
log + RMATracker windows) the benchmarks and tests assert against.
"""

from .minimod import MinimodResult, run_minimod, split_extents  # noqa: F401

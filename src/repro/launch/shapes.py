"""Assigned input-shape cells and their applicability rules.

LM transformer shapes are seq_len × global_batch.  decode_* / long_* lower
``serve_step`` (one new token against a seq_len KV cache), not train_step.
long_500k needs sub-quadratic attention: runs only for SSM/hybrid archs;
encoder-only archs have no decode step at all.

``STENCIL_SHAPES`` are the Minimod application cells — grid extents plus
the (Z×Y) domain decomposition, including the heterogeneous-rank cells
whose asymmetric Z extents exercise the PGAS asymmetric-allocation path
(consumed by :mod:`repro.apps.minimod`, ``examples/minimod.py`` and
``benchmarks/bench_minimod.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models import api as model_api
from repro.models.config import ModelConfig

__all__ = ["SHAPES", "Shape", "STENCIL_SHAPES", "StencilShape",
           "applicable", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class StencilShape:
    """One Minimod cell: global grid + (Z×Y) decomposition + time steps.

    ``weights`` (optional) makes the Z decomposition *asymmetric*: rank i
    owns a subdomain proportional to ``weights[i]`` (heterogeneous ranks,
    the paper's asymmetric-allocation scenario).  ``ny > 1`` additionally
    splits the Y axis (symmetric) for the 2-D decomposition.
    """

    name: str
    grid: Tuple[int, int, int]          # Z, Y, X
    steps: int
    nz: int
    ny: int = 1
    weights: Optional[Tuple[int, ...]] = None

    @property
    def ranks(self) -> int:
        return self.nz * self.ny


STENCIL_SHAPES = {
    "minimod_64": StencilShape("minimod_64", (64, 64, 64), 10, 8),
    "minimod_2d": StencilShape("minimod_2d", (64, 32, 64), 10, 4, ny=2),
    "minimod_hetero": StencilShape(
        "minimod_hetero", (60, 48, 48), 10, 4, weights=(3, 2, 2, 1)),
    "minimod_smoke": StencilShape("minimod_smoke", (48, 16, 16), 3, 4),
}


def skip_reason(cfg: ModelConfig, shape: Shape) -> Optional[str]:
    if shape.kind == "decode" and not model_api.has_decode(cfg):
        return "encoder-only arch: no decode step"
    if shape.kind == "prefill" and cfg.family == "audio":
        return None  # encoder prefill = a plain forward pass
    if shape.name == "long_500k" and not model_api.supports_long_context(cfg):
        return "full-attention arch: long_500k needs sub-quadratic decode state"
    return None


def applicable(cfg: ModelConfig, shape: Shape) -> bool:
    return skip_reason(cfg, shape) is None

"""Assigned input-shape cells and their applicability rules.

LM transformer shapes are seq_len × global_batch.  decode_* / long_* lower
``serve_step`` (one new token against a seq_len KV cache), not train_step.
long_500k needs sub-quadratic attention: runs only for SSM/hybrid archs;
encoder-only archs have no decode step at all.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models import api as model_api
from repro.models.config import ModelConfig

__all__ = ["SHAPES", "Shape", "applicable", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def skip_reason(cfg: ModelConfig, shape: Shape) -> Optional[str]:
    if shape.kind == "decode" and not model_api.has_decode(cfg):
        return "encoder-only arch: no decode step"
    if shape.kind == "prefill" and cfg.family == "audio":
        return None  # encoder prefill = a plain forward pass
    if shape.name == "long_500k" and not model_api.supports_long_context(cfg):
        return "full-attention arch: long_500k needs sub-quadratic decode state"
    return None


def applicable(cfg: ModelConfig, shape: Shape) -> bool:
    return skip_reason(cfg, shape) is None

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ---------------------------------------------------------------------------
# Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell on
# placeholder devices — no allocation, ShapeDtypeStruct in, compiled SPMD
# executable out.  Proves the distribution config is coherent and yields the
# memory/cost/collective numbers EXPERIMENTS.md §Dry-run / §Roofline read.
#
# The two lines above MUST precede any other import (jax locks the device
# count on first init).
# ---------------------------------------------------------------------------

import argparse
import dataclasses
import json
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, Shape, skip_reason
from repro.models import api as model_api
from repro.models import schema as sch
from repro.models.config import ModelConfig, ParallelCtx
from repro.serve.step import build_decode_step, build_prefill_step
from repro.train.optim import adafactor, adafactor_dim_axes, adamw, \
    cosine_schedule
from repro.train.step import build_train_step, opt_state_specs

ADAFACTOR_CUTOFF = 30e9   # params ≥ 30B train with Adafactor (HBM plan)


def pick_optimizer(cfg: ModelConfig, mesh, rules=None):
    n = cfg.param_count()
    lr = cosine_schedule(3e-4)
    if n >= ADAFACTOR_CUTOFF:
        return adafactor(lr, dim_axes=adafactor_dim_axes(cfg, mesh, rules)), \
            "adafactor"
    return adamw(lr), "adamw"


def default_microbatch(cfg: ModelConfig, shape: Shape, mesh) -> int:
    """Grad-accumulation so the remat carry fits the HBM plan
    (~1 sequence of 4k tokens per microstep for the big archs)."""
    dp = 1
    for ax in ("pod", "data"):
        dp *= mesh.shape.get(ax, 1)
    b_loc = max(shape.global_batch // dp, 1)
    tokens_per_seq = shape.seq_len
    target_tokens = 8192 if cfg.d_model <= 4096 else 4096
    seqs = max(target_tokens // tokens_per_seq, 1)
    mb = max(b_loc // seqs, 1)
    while b_loc % mb:
        mb -= 1
    return mb


def make_ctx(cfg: ModelConfig, shape: Shape, mesh, knobs: dict) -> ParallelCtx:
    mb = knobs.pop("microbatch", None) or default_microbatch(cfg, shape, mesh)
    return ParallelCtx.from_mesh(mesh, remat=True, microbatch=mb, **knobs)


def seq_sharded_for(cfg: ModelConfig, shape: Shape) -> bool:
    """Context(S)-shard the KV cache over 'data' when batch can't use it."""
    return shape.kind == "decode" and shape.global_batch == 1 and \
        cfg.family == "hybrid"


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               knobs: Optional[dict] = None, verbose: bool = True):
    """Returns (record dict, compiled) or a skip record."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if reason is not None:
        return ({"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "status": "skip", "reason": reason}, None)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    knobs = dict(knobs or {})
    # one DiompContext per cell: every collective the step traces is
    # recorded against this context's communicator table, giving the cell
    # record a faithful OMPCCL call log alongside the HLO-derived numbers
    from repro.core.context import DiompContext, use_default
    dctx = DiompContext(mesh=mesh)
    with use_default(dctx):
        ctx = make_ctx(cfg, shape, mesh, knobs)

        from jax.sharding import NamedSharding

        def with_sharding(structs, specs):
            """Attach the runtime's placement to every lowered struct, so the
            compiled module's argument layouts (and memory analysis) match the
            PGAS plan instead of a compiler guess."""
            return jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(
                    s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
                structs, specs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

        from repro.distributed.sharding import rules_for_ctx

        rules = rules_for_ctx(ctx)
        pspecs_all = sch.partition_specs(cfg, mesh, rules)
        pstructs = with_sharding(sch.param_structs(cfg), pspecs_all)
        t0 = time.time()

        if shape.kind == "train":
            opt, opt_name = pick_optimizer(cfg, mesh, rules)
            step = build_train_step(cfg, mesh, ctx, opt, optimizer_name=opt_name,
                                    global_batch=shape.global_batch)
            from repro.train.step import opt_state_specs as _oss
            ostructs = with_sharding(opt.state_structs(sch.param_structs(cfg)),
                                     _oss(cfg, mesh, opt_name, rules))
            bs_raw, bs_specs = model_api.batch_structs(
                cfg, mesh, shape.global_batch, shape.seq_len)
            bstructs = with_sharding(bs_raw, bs_specs)
            lowered = step.lower(pstructs, ostructs, bstructs,
                                 jax.ShapeDtypeStruct((), jnp.int32))
            tokens = shape.global_batch * shape.seq_len
            model_flops = 6.0 * cfg.active_param_count() * tokens
        elif shape.kind == "prefill":
            if cfg.family == "audio":
                # encoder "prefill" = the forward pass at full length
                ctx2 = dataclasses.replace(ctx, inference=True, remat=False)
                from jax.sharding import PartitionSpec as P
                from repro.core.compat import shard_map
                from repro.models.transformer import transformer_forward

                pspecs = sch.partition_specs(cfg, mesh)
                bs_raw, bspecs = model_api.batch_structs(
                    cfg, mesh, shape.global_batch, shape.seq_len)
                bstructs = with_sharding(bs_raw, bspecs)

                def enc(params, batch):
                    h, _ = transformer_forward(params, None, cfg, ctx2,
                                               embeds=batch["embeds"])
                    return h

                ba = model_api._batch_axes(mesh, shape.global_batch)
                step = jax.jit(shard_map(
                    enc, mesh=mesh, in_specs=(pspecs, bspecs),
                    out_specs=P(ba if ba else None)))
                lowered = step.lower(pstructs, bstructs)
            else:
                seqsh = False
                step = build_prefill_step(
                    cfg, mesh, ctx, B=shape.global_batch,
                    S_prompt=shape.seq_len, S_cache=shape.seq_len,
                    seq_sharded=seqsh)
                cs_raw, cs_specs = model_api.cache_structs(
                    cfg, mesh, ctx, shape.global_batch, shape.seq_len,
                    seq_sharded=seqsh)
                cstructs = with_sharding(cs_raw, cs_specs)
                ba = model_api._batch_axes(mesh, shape.global_batch)
                from jax.sharding import PartitionSpec as _P
                tstruct = jax.ShapeDtypeStruct(
                    (shape.global_batch,
                     shape.seq_len - (cfg.prefix_tokens or 0)), jnp.int32,
                    sharding=NamedSharding(mesh, _P(ba if ba else None)))
                lowered = step.lower(pstructs, tstruct, cstructs)
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * cfg.active_param_count() * tokens
        else:  # decode
            seqsh = seq_sharded_for(cfg, shape)
            step = build_decode_step(cfg, mesh, ctx, B=shape.global_batch,
                                     S=shape.seq_len, seq_sharded=seqsh)
            cs_raw, cs_specs = model_api.cache_structs(
                cfg, mesh, ctx, shape.global_batch, shape.seq_len,
                seq_sharded=seqsh)
            cstructs = with_sharding(cs_raw, cs_specs)
            ba = model_api._batch_axes(mesh, shape.global_batch)
            from jax.sharding import PartitionSpec as _P
            tstruct = jax.ShapeDtypeStruct(
                (shape.global_batch, 1), jnp.int32,
                sharding=NamedSharding(mesh, _P(ba if ba else None)))
            lowered = step.lower(pstructs, tstruct, cstructs)
            tokens = shape.global_batch
            model_flops = 2.0 * cfg.active_param_count() * tokens

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        "..", "..", ".."))
        from benchmarks.roofline import collective_bytes_from_hlo, roofline

        rep = roofline(arch, shape_name, mesh_name, chips, cost, hlo, model_flops)
        record = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "ok", "chips": chips,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            "ompccl_calls": {
                group: dict(calls) for group, calls in dctx.stats().items()},
            "knobs": {"microbatch": ctx.microbatch,
                      "dp_backend": ctx.dp_backend,
                      "grad_codec": ctx.grad_codec,
                      "explicit_dp": ctx.explicit_dp,
                      "expert2d": ctx.expert2d,
                      "layout": ctx.layout,
                      "fsdp_params": ctx.fsdp_params,
                      "gather_codec": ctx.gather_codec,
                      "use_ring_matmul": ctx.use_ring_matmul},
            **rep.row(),
        }
        if verbose:
            total_hbm = sum(v for v in record["memory"].values() if v) / 2**30
            print(f"[{arch} × {shape_name} × {mesh_name}] OK  "
                  f"lower {t_lower:.0f}s compile {t_compile:.0f}s  "
                  f"HBM/device ≈ {total_hbm:.2f} GiB  "
                  f"dominant={rep.dominant}  "
                  f"t=(c {rep.t_compute:.4f}, m {rep.t_memory:.4f}, "
                  f"x {rep.t_collective:.4f})s  "
                  f"useful={rep.useful_flops_fraction:.2f}")
            print("  memory_analysis:", record["memory"])
            print("  cost_analysis: flops/chip=%.3e bytes/chip=%.3e" %
                  (rep.flops_per_chip, rep.bytes_per_chip))
            print("  collectives/chip:", rep.coll_bytes_per_chip)
        return record, compiled


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=configs.all_archs(), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) cell")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--dp-backend", default="hierarchical",
                    choices=["flat", "hierarchical"])
    ap.add_argument("--grad-codec", default="none", choices=["none", "int8"])
    ap.add_argument("--ring-matmul", action="store_true")
    ap.add_argument("--implicit-dp", action="store_true")
    ap.add_argument("--expert2d", action="store_true",
                    help="MoE experts sharded over model x data (no d-gathers)")
    ap.add_argument("--no-fsdp-params", action="store_true",
                    help="inference WS: dense weights TP-sharded, no ZeRO-3")
    ap.add_argument("--gather-codec", default="none", choices=["none", "int8"],
                    help="int8-wire ZeRO-3 weight gathers (exact grad RS)")
    ap.add_argument("--layout", default="tp", choices=["tp", "dp_only"])
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args(argv)

    knobs = {"dp_backend": args.dp_backend, "grad_codec": args.grad_codec,
             "use_ring_matmul": args.ring_matmul,
             "explicit_dp": not args.implicit_dp,
             "expert2d": args.expert2d, "layout": args.layout,
             "fsdp_params": not args.no_fsdp_params,
             "gather_codec": args.gather_codec,
             "microbatch": args.microbatch}

    archs = configs.all_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                cell = f"{arch}__{shp}__{'multi' if mp else 'single'}"
                try:
                    rec, _ = lower_cell(arch, shp, multi_pod=mp,
                                        knobs=dict(knobs))
                except Exception as e:  # noqa: BLE001 — report, keep going
                    rec = {"arch": arch, "shape": shp,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "fail", "error": f"{type(e).__name__}: {e}"}
                    failures.append(cell)
                    print(f"[{cell}] FAIL: {rec['error'][:300]}")
                with open(os.path.join(args.out,
                                       f"{cell}__{args.tag}.json"), "w") as f:
                    json.dump(rec, f, indent=1, default=str)
    if failures:
        print(f"\n{len(failures)} cells FAILED: {failures}")
        sys.exit(1)
    print("\nall requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()

"""Serving driver: continuous batching with chunked prefill on the DiOMP
runtime (engine lifecycle + knob reference: docs/SERVING.md; overload
controls: docs/SERVING.md "Overload & SLOs").

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \\
      --requests 6 --max-new 8 --prefill-chunk 16

Passing any of --ttft-deadline-s / --total-deadline-s / --rate-per-s
arms the SLO layer: deadline-aware admission, bounded queue with
backpressure, load shedding, and staged degraded modes.  With deadlines
active, late requests are shed instead of served late, so the driver
reports done + shed == submitted rather than done == submitted.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time

import jax
import numpy as np

from repro import configs
from repro.launch.mesh import make_smoke_mesh
from repro.models import schema as sch
from repro.models.config import ParallelCtx
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b", choices=configs.all_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens per prefill device call "
                         "(1 = token-by-token baseline)")
    ap.add_argument("--page-tokens", type=int, default=64,
                    help="KV tokens per PGAS page")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples (with --top-k)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--high-watermark", type=float, default=0.92,
                    help="KV pressure above which the engine preempts")
    ap.add_argument("--ttft-deadline-s", type=float, default=None,
                    help="shed requests whose first token would miss this")
    ap.add_argument("--total-deadline-s", type=float, default=None,
                    help="cancel requests that cannot finish by this")
    ap.add_argument("--rate-per-s", type=float, default=None,
                    help="token-bucket admission rate limit")
    ap.add_argument("--burst", type=float, default=8.0,
                    help="token-bucket depth for --rate-per-s")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="hard queue bound: submissions beyond it reject")
    ap.add_argument("--queue-high", type=int, default=16,
                    help="backpressure/degrade watermark")
    ap.add_argument("--queue-low", type=int, default=4,
                    help="hysteresis watermark clearing backpressure")
    args = ap.parse_args(argv)

    slo = None
    if (args.ttft_deadline_s is not None or args.total_deadline_s is not None
            or args.rate_per_s is not None):
        from repro.serve.slo import SLOPolicy, TierPolicy
        slo = SLOPolicy(
            default_tier=TierPolicy(ttft_deadline_s=args.ttft_deadline_s,
                                    total_deadline_s=args.total_deadline_s,
                                    rate_per_s=args.rate_per_s,
                                    burst=args.burst),
            max_queue=args.max_queue, queue_high=args.queue_high,
            queue_low=args.queue_low)

    cfg = configs.get_reduced(args.arch)
    mesh = make_smoke_mesh(len(jax.devices()))
    ctx = ParallelCtx.from_mesh(mesh, remat=False, inference=True)
    params = sch.init_params(cfg, jax.random.PRNGKey(0))

    eng = ServeEngine(cfg, mesh, ctx, params, slots=args.slots, max_len=96,
                      prefill_chunk=args.prefill_chunk,
                      page_tokens=args.page_tokens,
                      temperature=args.temperature, top_k=args.top_k,
                      high_watermark=args.high_watermark, slo=slo)
    rng = np.random.RandomState(0)
    reqs = [eng.submit(rng.randint(0, cfg.vocab_size,
                                   size=rng.randint(2, args.max_prompt)),
                       max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    shed = sum(r.shed_reason is not None for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens in "
          f"{eng.steps} engine steps / {eng.device_calls} device calls "
          f"({dt:.1f}s incl. compile)")
    for i, r in enumerate(reqs[:4]):
        print(f"  req{i} prompt[{len(r.prompt)}] -> {r.out} "
              f"(prefill_steps={r.prefill_steps})")
    print("kv stats:", eng.kv_stats)
    print("latency:", json.dumps(eng.latency_stats(), default=float))
    if slo is not None:
        print(f"slo: {shed} shed, {len(eng.slo_log)} decision-log entries")
        assert done + shed == len(reqs)
    else:
        assert done == len(reqs)
    print("serve driver done")


if __name__ == "__main__":
    main()

"""Serving driver: continuous batching on the DiOMP runtime.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \\
      --requests 6 --max-new 8
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.launch.mesh import make_smoke_mesh
from repro.models import schema as sch
from repro.models.config import ParallelCtx
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b", choices=configs.all_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch)
    mesh = make_smoke_mesh(len(jax.devices()))
    ctx = ParallelCtx.from_mesh(mesh, remat=False, inference=True)
    params = sch.init_params(cfg, jax.random.PRNGKey(0))

    eng = ServeEngine(cfg, mesh, ctx, params, slots=args.slots, max_len=96)
    rng = np.random.RandomState(0)
    reqs = [eng.submit(rng.randint(0, cfg.vocab_size,
                                   size=rng.randint(2, 8)),
                       max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens in "
          f"{eng.steps} engine steps ({dt:.1f}s incl. compile)")
    for i, r in enumerate(reqs[:4]):
        print(f"  req{i} prompt={r.prompt.tolist()} -> {r.out}")
    print("kv stats:", eng.kv_stats)
    assert done == len(reqs)
    print("serve driver done")


if __name__ == "__main__":
    main()

"""Production training driver.

Wires the full DiOMP substrate: runtime registration (PGAS planning),
synthetic-shard data pipeline with async prefetch, the shard_map'd train
step (explicit OMPCCL gradient reduction), async atomic checkpointing with
auto-resume + elastic re-shard, and straggler monitoring with a CLOSED
eviction loop: when the monitor escalates (timing outliers, or a rank
death scheduled via ``--chaos-seed``/``--kill-rank-step``), the driver
checkpoints, shrinks the mesh to the surviving devices, restores from the
latest verified checkpoint, and keeps training (docs/RESILIENCE.md).

Smoke scale (default):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --reduced \\
      --steps 30 --batch 8 --seq 64

Full scale runs the same code path on the production mesh (remove
--reduced and set --mesh production under a real TPU runtime).
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.runtime import DiompRuntime
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.models import api as model_api
from repro.models import schema as sch
from repro.models.config import ParallelCtx
from repro.train.checkpoint import CheckpointManager
from repro.train.optim import (adafactor, adafactor_dim_axes, adamw,
                               cosine_schedule)
from repro.train.step import build_train_step
from repro.train.straggler import StragglerMonitor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=configs.all_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--mesh", choices=["smoke", "production"],
                    default="smoke")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-codec", default="none", choices=["none", "int8"])
    ap.add_argument("--dp-backend", default="hierarchical",
                    choices=["flat", "hierarchical"])
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="enable deterministic fault injection (FaultPlan)")
    ap.add_argument("--chaos-p", type=float, default=0.05,
                    help="per-dispatch fault probability under --chaos-seed")
    ap.add_argument("--kill-rank-step", type=int, default=None,
                    help="schedule a rank death at this step (elastic "
                         "restore exercise; requires --checkpoint-dir)")
    ap.add_argument("--max-restarts", type=int, default=1)
    args = ap.parse_args(argv)

    fault_plan = None
    if args.chaos_seed is not None:
        from repro.core.faults import FaultPlan
        fault_plan = FaultPlan(args.chaos_seed, p=args.chaos_p,
                               kinds=("drop", "fail", "timeout"))
        if args.kill_rank_step is not None:
            fault_plan.kill_rank(args.kill_rank_step,
                                 rank=len(jax.devices()) - 1)

    cfg = configs.get_reduced(args.arch) if args.reduced \
        else configs.get(args.arch)
    ndev = len(jax.devices())
    mesh = (make_production_mesh(multi_pod=True) if args.mesh == "production"
            else make_smoke_mesh(ndev))
    ctx = ParallelCtx.from_mesh(mesh, remat=True, microbatch=args.microbatch,
                                grad_codec=args.grad_codec,
                                dp_backend=args.dp_backend)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)} dp={ctx.dp} tp={ctx.tp}")

    # -- runtime: register every parameter into the PGAS plan ----------------
    from repro.core.context import DiompContext
    rt = DiompRuntime(mesh, context=DiompContext(
        mesh=mesh, segment_bytes=1 << 30, fault_plan=fault_plan))
    schema = sch.build_schema(cfg)
    for name, spec in schema.items():
        rt.register(name, spec.shape, spec.dtype, spec.axes)
    print(f"PGAS plan: {rt.bytes_in_use()/2**20:.1f} MiB/device in "
          f"{len(rt.table())} regions")

    # -- optimizer + step ------------------------------------------------------
    lr = cosine_schedule(args.lr, warmup=max(args.steps // 10, 1),
                         total=args.steps)
    if cfg.param_count() >= 30e9:
        opt, opt_name = adafactor(lr, dim_axes=adafactor_dim_axes(cfg, mesh)), \
            "adafactor"
    else:
        opt, opt_name = adamw(lr), "adamw"

    def build_step(mesh, ctx):
        return build_train_step(cfg, mesh, ctx, opt, optimizer_name=opt_name,
                                donate=False, global_batch=args.batch)

    step_fn = build_step(mesh, ctx)

    # -- init or resume ----------------------------------------------------------
    ckpt = CheckpointManager(args.checkpoint_dir, pool=rt.streams) \
        if args.checkpoint_dir else None
    start = 0
    if ckpt and args.resume and ckpt.latest() is not None:
        start, params, opt_state, extra = ckpt.restore(
            shard_fn=lambda name, arr: jax.device_put(arr))  # elastic re-shard
        params = {k: jnp.asarray(v) for k, v in params.items()}
        print(f"resumed from step {start}")
    else:
        params = sch.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = jax.jit(opt.init)(params)

    # -- data + monitoring ---------------------------------------------------------
    source = SyntheticLM(cfg, args.batch, args.seq, seed=17)
    prefetch = Prefetcher(source, depth=2, pool=rt.streams, start_step=start)
    # the eviction loop is CLOSED: on_evict raises a flag the driver acts on
    # (checkpoint -> shrink mesh -> restore), instead of only reporting
    evict_flag = {"requested": False}
    monitor = StragglerMonitor(
        on_prefetch_boost=lambda n: prefetch.boost(1),
        on_evict=lambda: evict_flag.update(requested=True))

    # -- the loop -------------------------------------------------------------------
    t_start = time.time()
    restarts = 0
    end = start + args.steps
    i = start
    while i < end:
        monitor.step_start()
        _, batch = prefetch.get()
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.asarray(i))
        loss = float(metrics["loss"])
        if fault_plan is not None and fault_plan.deaths_at(i):
            monitor.escalate(i, "rank-death")
        else:
            monitor.step_end(i)
        if i % 5 == 0 or i == end - 1:
            print(f"step {i:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"({(time.time()-t_start)/max(i-start+1,1):.2f}s/step)")
        if ckpt and (i + 1) % args.checkpoint_every == 0:
            ckpt.save(i + 1, jax.device_get(params),
                      jax.device_get(opt_state))
        i += 1
        if evict_flag["requested"]:
            evict_flag["requested"] = False
            if ckpt is None or restarts >= args.max_restarts or ndev <= 2:
                print(f"[elastic] eviction at step {i} but no restart "
                      "possible (need --checkpoint-dir, restart budget, "
                      ">2 devices); continuing degraded")
                continue
            # elastic restore: persist, shrink to the surviving devices,
            # resume from the latest VERIFIED checkpoint on the new mesh
            ckpt.wait()
            if ckpt.latest() is None:
                ckpt.save(i, jax.device_get(params),
                          jax.device_get(opt_state), blocking=True)
            ndev = max(ndev // 2, 2)
            mesh = make_smoke_mesh(ndev)
            ctx = ParallelCtx.from_mesh(
                mesh, remat=True, microbatch=args.microbatch,
                grad_codec=args.grad_codec, dp_backend=args.dp_backend)
            step_fn = build_step(mesh, ctx)
            i, params, opt_state, _ = ckpt.restore(
                shard_fn=lambda name, arr: jax.device_put(arr))
            params = {k: jnp.asarray(v) for k, v in params.items()}
            prefetch = Prefetcher(source, depth=2, pool=rt.streams,
                                  start_step=i)
            monitor.reset()
            restarts += 1
            print(f"[elastic] restart {restarts}: resumed step {i} on "
                  f"{ndev} devices (mesh {dict(mesh.shape)})")
    if ckpt:
        ckpt.wait()
        print(f"checkpoints: steps {ckpt.steps()}")
    if monitor.events:
        print(f"straggler events: {[(e.step, e.action) for e in monitor.events]}")
    if restarts:
        print(f"elastic restarts: {restarts}")
    rt.close()
    print("train driver done")
    return loss


if __name__ == "__main__":
    main()

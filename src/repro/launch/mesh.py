"""Production + smoke meshes.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).
"""

from __future__ import annotations

from repro.core.compat import make_mesh

__all__ = ["make_production_mesh", "make_smoke_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod prepends a 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types="auto")


def make_smoke_mesh(ndev: int = 8, *, pods: bool = True):
    """Small CPU mesh for tests/examples (8 virtual devices by default)."""
    if pods and ndev % 4 == 0:
        shape, axes = (2, ndev // 4, 2), ("pod", "data", "model")
    else:
        shape, axes = (max(ndev // 2, 1), min(ndev, 2)), ("data", "model")
    return make_mesh(shape, axes, axis_types="auto")

"""Production + smoke + multi-process meshes.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

Single-controller tests use :func:`make_smoke_mesh` (all devices live in
this process).  Multi-controller jobs — joined via
``diomp.init(coordinator=...)`` — use :func:`make_process_mesh`, which
validates the per-process device count and process count against the
actual runtime before building a mesh over the *global* device set, so a
mis-launched job fails with a topology error instead of a hang inside the
first collective.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

from repro.core.compat import make_mesh

__all__ = ["make_production_mesh", "make_smoke_mesh", "make_process_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod prepends a 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types="auto")


def _smoke_shape(ndev: int, pods: bool) -> Tuple[Tuple[int, ...],
                                                 Tuple[str, ...]]:
    if pods and ndev % 4 == 0:
        return (2, ndev // 4, 2), ("pod", "data", "model")
    return (max(ndev // 2, 1), min(ndev, 2)), ("data", "model")


def make_smoke_mesh(ndev: int = 8, *, pods: bool = True):
    """Small CPU mesh for tests/examples (8 virtual devices by default).

    ``ndev`` is validated against the devices this runtime actually has:
    asking for more than exist fails here with the fix spelled out, not
    deep inside ``jax.make_mesh`` with a shape error.
    """
    import jax

    if ndev <= 0:
        raise ValueError(f"ndev must be positive, got {ndev}")
    avail = jax.device_count()
    if ndev > avail:
        raise ValueError(
            f"make_smoke_mesh(ndev={ndev}) needs {ndev} devices but the "
            f"runtime has {avail} (local={jax.local_device_count()}, "
            f"processes={jax.process_count()}); raise "
            "--xla_force_host_platform_device_count in XLA_FLAGS or "
            "launch more processes")
    shape, axes = _smoke_shape(ndev, pods)
    return make_mesh(shape, axes, axis_types="auto")


def make_process_mesh(
    ndev_per_proc: Optional[int] = None,
    num_processes: Optional[int] = None,
    *,
    shape: Optional[Sequence[int]] = None,
    axes: Optional[Sequence[str]] = None,
    pods: bool = False,
):
    """Mesh over the **global** device set of a multi-controller job.

    ``ndev_per_proc`` / ``num_processes`` default to the runtime's actual
    topology; passing them pins the expectation and raises if the launch
    does not match (the harness passes both, so a worker that came up with
    the wrong device visibility dies loudly).  ``shape``/``axes`` override
    the default layout (e.g. ``shape=(n,), axes=("x",)`` for ring suites);
    the default is the smoke-mesh layout over ``ndev_per_proc *
    num_processes`` devices.

    Device order is jax's global order — process-major, so consecutive
    mesh positions within a process's block are process-local and every
    process computes the identical global layout.
    """
    import jax

    actual_local = jax.local_device_count()
    actual_procs = jax.process_count()
    if ndev_per_proc is None:
        ndev_per_proc = actual_local
    if num_processes is None:
        num_processes = actual_procs
    if ndev_per_proc != actual_local:
        raise ValueError(
            f"make_process_mesh(ndev_per_proc={ndev_per_proc}) but this "
            f"process sees {actual_local} local devices — set "
            "local_device_count in diomp.init / XLA_FLAGS before jax "
            "initializes")
    if num_processes != actual_procs:
        raise ValueError(
            f"make_process_mesh(num_processes={num_processes}) but the "
            f"job has {actual_procs} processes — check the "
            "jax.distributed launch (coordinator/num_processes/"
            "process_id)")
    total = ndev_per_proc * num_processes
    if jax.device_count() != total:
        raise ValueError(
            f"runtime reports {jax.device_count()} global devices, "
            f"expected {ndev_per_proc} x {num_processes} = {total}")
    if shape is None:
        shape, default_axes = _smoke_shape(total, pods)
        axes = tuple(axes) if axes is not None else default_axes
    else:
        shape = tuple(int(s) for s in shape)
        if axes is None:
            raise ValueError("explicit shape needs explicit axes")
        axes = tuple(axes)
    import math

    if math.prod(shape) != total:
        raise ValueError(
            f"mesh shape {shape} covers {math.prod(shape)} devices, the "
            f"job has {total}")
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} vs axes {axes} rank mismatch")
    return make_mesh(shape, axes, axis_types="auto")
